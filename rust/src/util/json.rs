//! Minimal JSON codec.
//!
//! The offline build has no `serde_json`, so the artifact interchange
//! (`artifacts/config.json`, lattice-table exports consumed by the python
//! compile path and by tests) uses this small, dependency-free value model.
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! kept as `f64` plus a lossless `i64` fast path (lattice indices fit in 53
//! bits in every table we export; larger values are serialized as strings
//! by the table layer).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer-valued number (round-trips exactly).
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_i64(v: &[i64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Int(x)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 && f.abs() < 9.0e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `obj["a"]["b"][2]` style path access.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !is_float {
            if let Ok(i) = s.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let doc = Json::obj(vec![
            ("name", Json::Str("llvq".into())),
            ("dims", Json::arr_i64(&[24, 8, 1])),
            ("ratio", Json::Num(0.921)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = doc.to_string_pretty();
        let back = parse(&s).unwrap();
        assert_eq!(doc, back);
        let s2 = doc.to_string_compact();
        assert_eq!(parse(&s2).unwrap(), doc);
    }

    #[test]
    fn parses_escapes_and_nesting() {
        let v = parse(r#"{"a": [1, 2.5, "x\ny", {"b": [[]]}], "c": -17}"#).unwrap();
        assert_eq!(v.path(&["c"]).unwrap().as_i64(), Some(-17));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[2].as_str(), Some("x\ny"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn big_ints_exact() {
        // 2^53-ish lattice index magnitudes must round-trip exactly
        let v = parse("[281474976710655, 9007199254740991]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(281474976710655));
        assert_eq!(a[1].as_i64(), Some(9007199254740991));
    }
}
