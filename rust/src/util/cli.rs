//! Declarative command-line parsing (no `clap` in the offline build).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generated `--help` text. Used by the `llvq` binary and
//! the examples.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_bool: bool,
}

#[derive(Default)]
pub struct Args {
    specs: Vec<ArgSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
    program: String,
    about: &'static str,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Self {
            about,
            ..Default::default()
        }
    }

    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_bool: true,
        });
        self
    }

    /// Parse from an iterator (typically `std::env::args().skip(n)`).
    /// On `--help` prints usage and exits.
    pub fn parse<I: Iterator<Item = String>>(mut self, iter: I) -> Result<Self, String> {
        let mut iter = iter.peekable();
        if self.program.is_empty() {
            self.program = "llvq".to_string();
        }
        while let Some(arg) = iter.next() {
            if arg == "--help" || arg == "-h" {
                eprintln!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n{}", self.usage()))?;
                let val = if spec.is_bool {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    iter.next()
                        .ok_or_else(|| format!("--{name} expects a value"))?
                };
                self.values.insert(name, val);
            } else {
                self.positional.push(arg);
            }
        }
        Ok(self)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nflags:\n", self.about);
        for spec in &self.specs {
            let d = spec
                .default
                .as_deref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    pub fn get(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("flag --{name} missing or not a usize"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("flag --{name} missing or not a u64"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("flag --{name} missing or not an f64"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(|x| x.to_string())
    }

    #[test]
    fn parses_flags_and_defaults() {
        let a = Args::new("test")
            .flag("bits", "2", "bitrate")
            .flag("seed", "0", "rng seed")
            .switch("verbose", "chatty")
            .parse(argv("--bits 4 --verbose pos1"))
            .unwrap();
        assert_eq!(a.get_usize("bits"), 4);
        assert_eq!(a.get_u64("seed"), 0); // default
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = Args::new("t")
            .flag("rate", "1.0", "r")
            .parse(argv("--rate=2.5"))
            .unwrap();
        assert!((a.get_f64("rate") - 2.5).abs() < 1e-12);
    }

    #[test]
    fn unknown_flag_rejected() {
        let r = Args::new("t").parse(argv("--nope 3"));
        assert!(r.is_err());
    }
}
