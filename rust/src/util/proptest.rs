//! Minimal property-testing driver (no `proptest` crate offline).
//!
//! A property is a closure over a seeded RNG; the driver runs it for many
//! cases and reports the failing seed, so failures are reproducible with
//! `check_with_seed`. Used by `rust/tests/proptests.rs` to pin the crate's
//! core invariants (index bijectivity, decoder optimality, pipeline
//! conservation laws).

use crate::util::rng::Xoshiro256pp;

/// Outcome of a property over one generated case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `cases` generated cases. Panics with the failing case
/// seed and message on the first violation.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> CaseResult,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Xoshiro256pp::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with LLVQ_PROP_SEED={seed}"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_with_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> CaseResult,
{
    let mut rng = Xoshiro256pp::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

fn base_seed() -> u64 {
    std::env::var("LLVQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_5EED)
}

/// Run `f` with the global panic hook silenced (for tests that provoke
/// expected panics), serialized process-wide: parallel tests swapping the
/// hook race each other otherwise — one test can capture another's no-op
/// hook as "previous" and leave panics silenced for the rest of the run.
pub fn with_silenced_panics<R>(f: impl FnOnce() -> R) -> R {
    use std::sync::Mutex;
    static HOOK_LOCK: Mutex<()> = Mutex::new(());
    let _guard = HOOK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let r = f();
    std::panic::set_hook(prev);
    r
}

/// RAII temp file for artifact round-trip tests: a unique path under the
/// system temp dir, removed on drop — so a failing assertion between
/// `save` and the old success-path `remove_file` no longer leaks `.llvqm`
/// files into `/tmp`.
pub struct TempArtifact(std::path::PathBuf);

impl TempArtifact {
    /// `llvq-<tag>-<pid>-<seq>.<ext>` — pid separates test binaries,
    /// the sequence number separates threaded tests within one binary.
    pub fn new(tag: &str, ext: &str) -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "llvq-{tag}-{}-{}.{ext}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        Self(path)
    }

    pub fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TempArtifact {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("commutativity", 50, |rng| {
            let a = rng.next_f64();
            let b = rng.next_f64();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    fn temp_artifact_removes_file_on_drop() {
        let kept;
        {
            let t = TempArtifact::new("proptest-guard", "llvqm");
            std::fs::write(t.path(), b"x").unwrap();
            assert!(t.path().exists());
            kept = t.path().to_path_buf();
        }
        assert!(!kept.exists(), "drop guard must remove the artifact");
        // distinct instances never collide
        let a = TempArtifact::new("proptest-guard", "llvqm");
        let b = TempArtifact::new("proptest-guard", "llvqm");
        assert_ne!(a.path(), b.path());
    }

    #[test]
    #[should_panic(expected = "property 'sometimes-false'")]
    fn failing_property_reports() {
        check("sometimes-false", 100, |rng| {
            if rng.next_f64() < 0.7 {
                Ok(())
            } else {
                Err("drew a large value".into())
            }
        });
    }
}
