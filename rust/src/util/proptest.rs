//! Minimal property-testing driver (no `proptest` crate offline).
//!
//! A property is a closure over a seeded RNG; the driver runs it for many
//! cases and reports the failing seed, so failures are reproducible with
//! `check_with_seed`. Used by `rust/tests/proptests.rs` to pin the crate's
//! core invariants (index bijectivity, decoder optimality, pipeline
//! conservation laws).
//!
//! ## Persisted regression seeds
//!
//! Mirroring the `proptest` crate's `proptest-regressions/` convention,
//! [`check`] replays recorded seeds **before** generating fresh random
//! cases. When a property fails, the panic message prints the failing
//! seed; to pin it forever, append that seed to
//! `proptest-regressions/<stem>.seeds` at the repo root and commit the
//! file — CI then replays every recorded failure on every run, so a
//! once-found bug cannot silently regress. `<stem>` is the property name
//! passed to `check` with every character outside `[A-Za-z0-9._-]`
//! replaced by `-` (so `check("codec roundtrip", …)` reads
//! `codec-roundtrip.seeds`). One seed per line, decimal or `0x`-prefixed
//! hex; `#` starts a comment. See `proptest-regressions/README.md`.

use crate::util::rng::Xoshiro256pp;

/// Outcome of a property over one generated case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over any recorded regression seeds (see the module docs)
/// and then `cases` generated cases. Panics with the failing case seed
/// and message on the first violation.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> CaseResult,
{
    for seed in regression_seeds(name) {
        let mut rng = Xoshiro256pp::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on RECORDED regression seed {seed:#x} \
                 (proptest-regressions/{}.seeds): {msg}",
                seeds_file_stem(name)
            );
        }
    }
    // Miri interprets every case ~1000x slower than native; a couple of
    // fresh cases per property (plus every recorded regression seed,
    // which always replay in full above) keeps CI's miri job useful
    // without multi-hour runs.
    let cases = if cfg!(miri) { cases.min(2) } else { cases };
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Xoshiro256pp::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with LLVQ_PROP_SEED={seed}"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_with_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> CaseResult,
{
    let mut rng = Xoshiro256pp::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

fn base_seed() -> u64 {
    std::env::var("LLVQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_5EED)
}

/// Parse a `proptest-regressions` seeds file: one u64 per line, decimal
/// or `0x`-prefixed hex; blank lines and `#` comments are skipped, as are
/// unparseable lines (a malformed seed must not mask the real property).
fn parse_seeds(text: &str) -> Vec<u64> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| match l.strip_prefix("0x") {
            Some(h) => u64::from_str_radix(h, 16).ok(),
            None => l.parse().ok(),
        })
        .collect()
}

/// File stem for a property name: every character outside `[A-Za-z0-9._-]`
/// becomes `-`.
fn seeds_file_stem(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                c
            } else {
                '-'
            }
        })
        .collect()
}

/// Seeds recorded for `name` under the committed `proptest-regressions/`
/// directory at the crate root (empty when no file exists).
fn regression_seeds(name: &str) -> Vec<u64> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("proptest-regressions")
        .join(format!("{}.seeds", seeds_file_stem(name)));
    match std::fs::read_to_string(path) {
        Ok(text) => parse_seeds(&text),
        Err(_) => Vec::new(),
    }
}

/// Run `f` with the global panic hook silenced (for tests that provoke
/// expected panics), serialized process-wide: parallel tests swapping the
/// hook race each other otherwise — one test can capture another's no-op
/// hook as "previous" and leave panics silenced for the rest of the run.
pub fn with_silenced_panics<R>(f: impl FnOnce() -> R) -> R {
    use std::sync::Mutex;
    static HOOK_LOCK: Mutex<()> = Mutex::new(());
    let _guard = HOOK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let r = f();
    std::panic::set_hook(prev);
    r
}

/// RAII temp file for artifact round-trip tests: a unique path under the
/// system temp dir, removed on drop — so a failing assertion between
/// `save` and the old success-path `remove_file` no longer leaks `.llvqm`
/// files into `/tmp`.
pub struct TempArtifact(std::path::PathBuf);

impl TempArtifact {
    /// `llvq-<tag>-<pid>-<seq>.<ext>` — pid separates test binaries,
    /// the sequence number separates threaded tests within one binary.
    pub fn new(tag: &str, ext: &str) -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "llvq-{tag}-{}-{}.{ext}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        Self(path)
    }

    pub fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TempArtifact {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("commutativity", 50, |rng| {
            let a = rng.next_f64();
            let b = rng.next_f64();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    fn temp_artifact_removes_file_on_drop() {
        let kept;
        {
            let t = TempArtifact::new("proptest-guard", "llvqm");
            std::fs::write(t.path(), b"x").unwrap();
            assert!(t.path().exists());
            kept = t.path().to_path_buf();
        }
        assert!(!kept.exists(), "drop guard must remove the artifact");
        // distinct instances never collide
        let a = TempArtifact::new("proptest-guard", "llvqm");
        let b = TempArtifact::new("proptest-guard", "llvqm");
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn seeds_files_parse_and_stems_sanitize() {
        let text = "# shrunk failures\n12345\n0xC0FFEE5EED\n\n  0x10  \nnot-a-seed\n";
        assert_eq!(parse_seeds(text), vec![12345, 0xC0FF_EE5E_ED, 0x10]);
        assert_eq!(seeds_file_stem("codec roundtrip (v2)"), "codec-roundtrip--v2-");
        assert_eq!(seeds_file_stem("pool-parity-t4"), "pool-parity-t4");
        // no file recorded → no replays
        assert!(regression_seeds("no-such-property-ever").is_empty());
    }

    #[test]
    #[should_panic(expected = "property 'sometimes-false'")]
    fn failing_property_reports() {
        check("sometimes-false", 100, |rng| {
            if rng.next_f64() < 0.7 {
                Ok(())
            } else {
                Err("drew a large value".into())
            }
        });
    }
}
