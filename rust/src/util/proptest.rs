//! Minimal property-testing driver (no `proptest` crate offline).
//!
//! A property is a closure over a seeded RNG; the driver runs it for many
//! cases and reports the failing seed, so failures are reproducible with
//! `check_with_seed`. Used by `rust/tests/proptests.rs` to pin the crate's
//! core invariants (index bijectivity, decoder optimality, pipeline
//! conservation laws).

use crate::util::rng::Xoshiro256pp;

/// Outcome of a property over one generated case.
pub type CaseResult = Result<(), String>;

/// Run `prop` over `cases` generated cases. Panics with the failing case
/// seed and message on the first violation.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> CaseResult,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Xoshiro256pp::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with LLVQ_PROP_SEED={seed}"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn check_with_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Xoshiro256pp) -> CaseResult,
{
    let mut rng = Xoshiro256pp::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

fn base_seed() -> u64 {
    std::env::var("LLVQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_5EED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("commutativity", 50, |rng| {
            let a = rng.next_f64();
            let b = rng.next_f64();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'sometimes-false'")]
    fn failing_property_reports() {
        check("sometimes-false", 100, |rng| {
            if rng.next_f64() < 0.7 {
                Ok(())
            } else {
                Err("drew a large value".into())
            }
        });
    }
}
