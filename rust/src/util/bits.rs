//! MSB-first bit-level I/O — the substrate of the packed `.llvqm` model
//! format (paper §3.3: bijective indices "convert to and from bitstrings
//! without materializing the codebook").
//!
//! [`BitWriter`] packs arbitrary ≤64-bit fields into a byte buffer,
//! most-significant bit first, so a hex dump reads in field order and the
//! format is independent of host endianness. [`BitReader`] is the exact
//! inverse. Both are branch-light and allocation-free per field, which is
//! what the per-row code streams in `pipeline::gptq` and the block-parallel
//! dequantization in `model::packed` need.

/// MSB-first bit sink over a growable byte buffer.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Partial byte being filled (top `nbits` of the value are valid,
    /// stored left-aligned as they are shifted in from the right).
    cur: u8,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self {
            buf: Vec::with_capacity(bytes),
            cur: 0,
            nbits: 0,
        }
    }

    /// Append the low `width` bits of `v` (MSB of the field first).
    /// `width` may be 0 (no-op) up to 64.
    pub fn write(&mut self, v: u64, width: u32) {
        debug_assert!(width <= 64, "field width {width} > 64");
        if width < 64 {
            debug_assert!(v >> width == 0, "value {v:#x} exceeds {width} bits");
        }
        let mut left = width;
        while left > 0 {
            let take = (8 - self.nbits).min(left);
            let shift = left - take;
            let bits = ((v >> shift) & ((1u64 << take) - 1)) as u8;
            // `take` can be 8 (empty partial byte, ≥8 bits left), where
            // `cur << 8` would overflow the u8 shift; cur is 0 then.
            self.cur = if take == 8 { bits } else { (self.cur << take) | bits };
            self.nbits += take;
            left -= take;
            if self.nbits == 8 {
                self.buf.push(self.cur);
                self.cur = 0;
                self.nbits = 0;
            }
        }
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush the final partial byte (zero-padded on the right) and return
    /// the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push(self.cur << (8 - self.nbits));
        }
        self.buf
    }
}

/// MSB-first bit source over a byte slice.
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Absolute bit position of the next read.
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self { data, pos: 0 }
    }

    /// Read the next `width` bits (0..=64) as the low bits of a u64.
    /// Panics when the stream is exhausted — callers validate payload
    /// sizes up front (`model::packed` does).
    pub fn read(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64, "field width {width} > 64");
        assert!(
            self.pos + width as usize <= self.data.len() * 8,
            "BitReader overrun: need {width} bits at bit {}, stream has {}",
            self.pos,
            self.data.len() * 8
        );
        let mut out = 0u64;
        let mut left = width;
        while left > 0 {
            let byte = self.data[self.pos / 8];
            let avail = 8 - (self.pos % 8) as u32;
            let take = avail.min(left);
            let shift = avail - take;
            let bits = ((byte >> shift) as u64) & ((1u64 << take) - 1);
            out = (out << take) | bits;
            self.pos += take as usize;
            left -= take;
        }
        out
    }

    /// Absolute bit position of the next read.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Bits left in the stream (including any trailing pad bits).
    pub fn bits_remaining(&self) -> usize {
        self.data.len() * 8 - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn msb_first_known_pattern() {
        let mut w = BitWriter::new();
        w.write(0b1, 1);
        w.write(0xFF, 8);
        assert_eq!(w.bit_len(), 9);
        let bytes = w.finish();
        // 1 then 11111111, zero-padded: 1111_1111 1000_0000
        assert_eq!(bytes, vec![0xFF, 0x80]);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(1), 0b1);
        assert_eq!(r.read(8), 0xFF);
        assert_eq!(r.bits_remaining(), 7);
        assert_eq!(r.read(7), 0); // pad bits
    }

    #[test]
    fn zero_and_full_width_fields() {
        let mut w = BitWriter::new();
        w.write(0, 0); // no-op
        w.write(u64::MAX, 64);
        w.write(0, 0);
        w.write(0xDEAD_BEEF_CAFE_F00D, 64);
        let bytes = w.finish();
        assert_eq!(bytes.len(), 16);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(0), 0);
        assert_eq!(r.read(64), u64::MAX);
        assert_eq!(r.read(64), 0xDEAD_BEEF_CAFE_F00D);
    }

    #[test]
    fn random_field_sequences_roundtrip() {
        let mut rng = Xoshiro256pp::new(0xB175);
        for _ in 0..200 {
            let n = 1 + rng.next_range(40) as usize;
            let fields: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let width = rng.next_range(65) as u32;
                    let v = if width == 0 {
                        0
                    } else if width == 64 {
                        rng.next_u64()
                    } else {
                        rng.next_u64() & ((1u64 << width) - 1)
                    };
                    (v, width)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, width) in &fields {
                w.write(v, width);
            }
            let total: usize = fields.iter().map(|&(_, wd)| wd as usize).sum();
            assert_eq!(w.bit_len(), total);
            let bytes = w.finish();
            assert_eq!(bytes.len(), total.div_ceil(8));
            let mut r = BitReader::new(&bytes);
            for &(v, width) in &fields {
                assert_eq!(r.read(width), v, "width {width}");
            }
            assert_eq!(r.bit_pos(), total);
        }
    }

    #[test]
    #[should_panic(expected = "BitReader overrun")]
    fn overrun_panics() {
        let bytes = [0u8; 2];
        let mut r = BitReader::new(&bytes);
        r.read(17);
    }
}
