//! Lint driver: collect the tree, run the rules, render deterministically.
//!
//! Two entry points:
//! * [`lint_files`] — pure: takes `(repo-relative path, text)` pairs and
//!   returns sorted findings. Tests feed it virtual trees to prove each
//!   rule fires (and doesn't) without touching the working copy.
//! * [`run_lint`] — walks a real repo root (`rust/src`, `rust/tests`,
//!   `rust/benches`, `examples`), skipping the committed lint fixtures,
//!   and lints what it finds. `scripts/verify.sh` and CI's lint job call
//!   this through `llvq lint`.
//!
//! Output is deterministic by construction: files are read in sorted
//! path order and findings are sorted by (file, line, rule, message), so
//! `--json` output is byte-identical across runs and machines.

use std::fs;
use std::path::{Path, PathBuf};

use crate::lint::rules::{self, Finding};
use crate::lint::source::SourceFile;
use crate::util::json::Json;

/// Directories under the repo root that hold lintable Rust sources.
pub const LINT_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Path components excluded from the walk: committed rule fixtures are
/// *deliberately* dirty, and `target/` is build output.
const SKIP_COMPONENTS: &[&str] = &["lint_fixtures", "target"];

/// Lint a virtual tree of `(repo-relative path, source text)` pairs.
/// The docs-sync rule does not run here — virtual trees (rule fixtures,
/// unit tests) carry no docs; use [`lint_files_with_docs`] for that.
pub fn lint_files(inputs: &[(String, String)]) -> Vec<Finding> {
    lint_inputs(inputs, None)
}

/// [`lint_files`] plus the docs-sync rule: `docs` holds the
/// `(repo-relative path, text)` pairs for the [`rules::DOC_FILES`] that
/// exist — an expected doc absent from it is reported as a finding.
pub fn lint_files_with_docs(
    inputs: &[(String, String)],
    docs: &[(String, String)],
) -> Vec<Finding> {
    lint_inputs(inputs, Some(docs))
}

fn lint_inputs(inputs: &[(String, String)], docs: Option<&[(String, String)]>) -> Vec<Finding> {
    let files: Vec<SourceFile> = inputs
        .iter()
        .map(|(p, t)| SourceFile::parse(p, t))
        .collect();
    let mut out = Vec::new();
    for f in &files {
        rules::check_file(f, &mut out);
    }
    rules::check_repo(&files, &mut out);
    if let Some(docs) = docs {
        rules::check_docs(&files, docs, &mut out);
    }
    out.sort();
    out.dedup();
    out
}

/// Walk `root` and lint every tracked `.rs` source, plus the committed
/// reference docs for the docs-sync rule. `rule` restricts the report to
/// one rule by name (the full set still runs; filtering is on output so
/// cross-rule state never diverges).
pub fn run_lint(root: &Path, rule: Option<&str>) -> Result<Vec<Finding>, String> {
    if let Some(r) = rule {
        if !rules::known_rule(r) {
            let names: Vec<&str> = rules::RULES.iter().map(|(n, _)| *n).collect();
            return Err(format!("unknown rule `{r}` (have: {})", names.join(", ")));
        }
    }
    let inputs = collect_inputs(root)?;
    if inputs.is_empty() {
        return Err(format!(
            "no Rust sources under {} (expected {})",
            root.display(),
            LINT_DIRS.join(", ")
        ));
    }
    let mut docs: Vec<(String, String)> = Vec::new();
    for name in rules::DOC_FILES {
        if let Ok(text) = fs::read_to_string(root.join(name)) {
            docs.push((name.to_string(), text));
        }
    }
    let mut findings = lint_files_with_docs(&inputs, &docs);
    if let Some(r) = rule {
        findings.retain(|f| f.rule == r);
    }
    Ok(findings)
}

/// Read every lintable source under `root`, sorted by relative path.
pub fn collect_inputs(root: &Path) -> Result<Vec<(String, String)>, String> {
    let mut paths: Vec<PathBuf> = Vec::new();
    for dir in LINT_DIRS {
        let abs = root.join(dir);
        if abs.is_dir() {
            walk(&abs, &mut paths)?;
        }
    }
    let mut inputs = Vec::with_capacity(paths.len());
    for p in paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            fs::read_to_string(&p).map_err(|e| format!("reading {}: {e}", p.display()))?;
        inputs.push((rel, text));
    }
    inputs.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(inputs)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in rd {
        entries.push(e.map_err(|e| format!("reading {}: {e}", dir.display()))?.path());
    }
    entries.sort();
    for p in entries {
        let name = p.file_name().map(|n| n.to_string_lossy().to_string());
        if let Some(n) = &name {
            if SKIP_COMPONENTS.contains(&n.as_str()) || n.starts_with('.') {
                continue;
            }
        }
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// `file:line: [rule] message` per finding plus a one-line summary —
/// stable, grep-able, and clickable in most terminals.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    if findings.is_empty() {
        out.push_str("lint clean: 0 findings\n");
    } else {
        out.push_str(&format!("lint: {} finding(s)\n", findings.len()));
    }
    out
}

/// Compact JSON report; keys and array order are deterministic.
pub fn render_json(findings: &[Finding]) -> String {
    let arr = findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Int(f.line as i64)),
                ("rule", Json::Str(f.rule.to_string())),
                ("message", Json::Str(f.message.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("findings", Json::Arr(arr)),
        ("total", Json::Int(findings.len() as i64)),
    ])
    .to_string_compact()
}

/// Walk upward from `start` to the first directory that looks like this
/// repo's root (has both `Cargo.toml` and `rust/`).
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(d) = cur {
        if d.join("Cargo.toml").is_file() && d.join("rust").is_dir() {
            return Some(d);
        }
        cur = d.parent().map(|p| p.to_path_buf());
    }
    None
}
