//! The rule set: invariants the repo already relies on, now enforced.
//!
//! Each rule matches token shapes or line patterns from
//! [`crate::lint::source::SourceFile`] — deliberately conservative
//! patterns with near-zero false positives on this tree, escapable (where
//! escape makes sense) via an inline `lint:allow` comment directive
//! carrying a reason. See `LINTS.md` at the repo root for the rationale
//! and one worked example per rule.

use crate::lint::source::{SourceFile, TokKind};

/// One reported violation. The derived ordering (file, line, rule,
/// message) is the engine's deterministic output order.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

pub const SAFETY_COMMENT: &str = "safety-comment";
pub const NO_PANIC_SERVING: &str = "no-panic-serving";
pub const LOCK_POISON: &str = "lock-poison";
pub const TARGET_FEATURE_UNSAFE: &str = "target-feature-unsafe";
pub const STATS_WIRE_ORDER: &str = "stats-wire-order";
pub const DOCS_SYNC: &str = "docs-sync";
pub const ALLOW_SYNTAX: &str = "allow-syntax";

/// `(name, summary)` for `--rule` validation and the text report footer.
pub const RULES: &[(&str, &str)] = &[
    (
        SAFETY_COMMENT,
        "every unsafe block/fn/impl carries a SAFETY: justification",
    ),
    (
        NO_PANIC_SERVING,
        "no unwrap/expect/panic in non-test serving-path modules",
    ),
    (
        LOCK_POISON,
        "lock() results recover from poisoning, never bare-unwrap",
    ),
    (
        TARGET_FEATURE_UNSAFE,
        "target_feature fns are unsafe and live behind quant::kernel dispatch",
    ),
    (
        STATS_WIRE_ORDER,
        "STATS field order and wire-protocol verbs stay consistent everywhere",
    ),
    (
        DOCS_SYNC,
        "docs/PROTOCOL.md and docs/OPERATIONS.md cover every wire verb, HTTP \
         route, and STATS field",
    ),
    (
        ALLOW_SYNTAX,
        "lint:allow directives name a real rule and carry a reason",
    ),
];

/// Non-test serving-path modules rule 2 guards: a panic here tears down a
/// worker mid-request. (`main.rs` is CLI startup — usage errors exit on
/// purpose — and stays out of scope; see LINTS.md.)
pub const SERVING_MODULES: &[&str] = &[
    "rust/src/coordinator.rs",
    "rust/src/http/api.rs",
    "rust/src/http/wire.rs",
    "rust/src/model/backend.rs",
    "rust/src/model/kvpage.rs",
    "rust/src/model/registry.rs",
    "rust/src/util/threadpool.rs",
];

/// Every verb a `write!`/`writeln!` reply may lead with: v1/v2 requests
/// echoed in errors plus the reply verbs themselves.
pub const WIRE_VERBS: &[&str] = &[
    "CLOSE", "ERR", "FEED", "GEN", "NEXT", "OK", "OPEN", "QUEUED", "QUIT", "STATS", "TOK",
];

/// Request verbs the coordinator must recognize as string literals.
pub const REQUEST_VERBS: &[&str] = &["OPEN", "FEED", "GEN", "CLOSE", "NEXT", "STATS", "QUIT"];

/// Lowercase event verbs the sim trace format commits to.
pub const TRACE_VERBS: &[&str] = &["open", "feed", "gen", "close"];

/// Documentation files the docs-sync rule keeps in lockstep with the
/// wire surface: PROTOCOL.md documents verbs/routes/error codes,
/// OPERATIONS.md glosses every STATS field.
pub const DOC_FILES: &[&str] = &["docs/PROTOCOL.md", "docs/OPERATIONS.md"];

/// Routes the HTTP front door serves. `docs/PROTOCOL.md` must document
/// each, and `rust/src/http/api.rs` must keep each as a string literal.
pub const HTTP_ROUTES: &[&str] = &["/v1/completions", "/v1/models", "/metrics"];

pub fn known_rule(name: &str) -> bool {
    RULES.iter().any(|(n, _)| *n == name)
}

/// Run every per-file rule over `f`.
pub fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    check_safety_comments(f, out);
    check_no_panic_serving(f, out);
    check_lock_poison(f, out);
    check_target_feature(f, out);
    check_reply_verbs(f, out);
    check_allow_syntax(f, out);
}

/// Run the repo-level consistency rule (STATS field order across files)
/// over the whole file set.
pub fn check_repo(files: &[SourceFile], out: &mut Vec<Finding>) {
    // verb/route presence checks don't need the canonical field list —
    // they must fire even when the coordinator isn't in the input set
    for f in files {
        if f.path.ends_with("sim/trace.rs") {
            check_trace_verbs_present(f, out);
        }
        if f.path.ends_with("src/http/api.rs") {
            check_http_routes_present(f, out);
        }
    }
    let coordinator = files
        .iter()
        .find(|f| f.path.ends_with("src/coordinator.rs"));
    let canon = match coordinator.and_then(extract_canonical_fields) {
        Some(c) => c,
        None => {
            if let Some(f) = coordinator {
                out.push(Finding {
                    file: f.path.clone(),
                    line: 1,
                    rule: STATS_WIRE_ORDER,
                    message: "could not locate the canonical `fields: vec![..]` list in \
                              Metrics::snapshot"
                        .to_string(),
                });
            }
            return;
        }
    };
    check_canonical_shape(&canon, out);
    if let Some(f) = coordinator {
        check_stats_doc_table(f, &canon.fields, out);
        check_request_verbs_present(f, out);
    }
    for f in files {
        check_field_order_lines(f, &canon.fields, out);
    }
}

/// Run the docs consistency rule: the committed reference docs must
/// cover every wire verb, HTTP route, and STATS snapshot field the
/// sources actually speak. `docs` carries the doc texts that exist;
/// a [`DOC_FILES`] entry absent from it is itself a finding.
pub fn check_docs(files: &[SourceFile], docs: &[(String, String)], out: &mut Vec<Finding>) {
    let doc = |name: &str| docs.iter().find(|(p, _)| p == name).map(|(_, t)| t.as_str());
    for name in DOC_FILES {
        if doc(name).is_none() {
            out.push(Finding {
                file: name.to_string(),
                line: 1,
                rule: DOCS_SYNC,
                message: format!(
                    "{name} is missing — it is the canonical wire/operations reference"
                ),
            });
        }
    }
    if let Some(proto) = doc("docs/PROTOCOL.md") {
        // WIRE_VERBS is a superset of REQUEST_VERBS, so one sweep covers
        // both request and reply vocabularies
        for verb in WIRE_VERBS {
            if !contains_word(proto, verb) {
                out.push(Finding {
                    file: "docs/PROTOCOL.md".to_string(),
                    line: 1,
                    rule: DOCS_SYNC,
                    message: format!("wire verb `{verb}` is undocumented in docs/PROTOCOL.md"),
                });
            }
        }
        for route in HTTP_ROUTES {
            if !proto.contains(route) {
                out.push(Finding {
                    file: "docs/PROTOCOL.md".to_string(),
                    line: 1,
                    rule: DOCS_SYNC,
                    message: format!("HTTP route `{route}` is undocumented in docs/PROTOCOL.md"),
                });
            }
        }
        if !proto.contains("kv-oom") {
            out.push(Finding {
                file: "docs/PROTOCOL.md".to_string(),
                line: 1,
                rule: DOCS_SYNC,
                message: "the `kv-oom` error code is undocumented in docs/PROTOCOL.md"
                    .to_string(),
            });
        }
    }
    if let Some(ops) = doc("docs/OPERATIONS.md") {
        let canon = files
            .iter()
            .find(|f| f.path.ends_with("src/coordinator.rs"))
            .and_then(extract_canonical_fields);
        if let Some(canon) = canon {
            for field in &canon.fields {
                if !contains_word(ops, field) {
                    out.push(Finding {
                        file: "docs/OPERATIONS.md".to_string(),
                        line: 1,
                        rule: DOCS_SYNC,
                        message: format!(
                            "STATS field `{field}` has no glossary entry in docs/OPERATIONS.md"
                        ),
                    });
                }
            }
        }
    }
}

/// `word` occurs in `text` with non-identifier characters (or text
/// boundaries) on both sides — `kv_quant` never matches inside
/// `kv_quantized`, `GEN` never matches inside `REGEN`.
fn contains_word(text: &str, word: &str) -> bool {
    let bytes = text.as_bytes();
    let is_word = |b: u8| b.is_ascii_alphanumeric() || b == b'_';
    let mut from = 0usize;
    while let Some(rel) = text[from..].find(word) {
        let at = from + rel;
        let end = at + word.len();
        let prev_ok = at == 0 || !is_word(bytes[at - 1]);
        let next_ok = end == text.len() || !is_word(bytes[end]);
        if prev_ok && next_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// The HTTP front door must keep serving every documented route.
fn check_http_routes_present(f: &SourceFile, out: &mut Vec<Finding>) {
    for route in HTTP_ROUTES {
        let present = f
            .toks
            .iter()
            .any(|t| t.kind == TokKind::Str && t.text == *route);
        if !present {
            out.push(Finding {
                file: f.path.clone(),
                line: 1,
                rule: DOCS_SYNC,
                message: format!(
                    "HTTP route `{route}` no longer appears as a string literal — the \
                     front door must keep serving it"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- rule 1

fn check_safety_comments(f: &SourceFile, out: &mut Vec<Finding>) {
    for site in f.unsafe_sites() {
        if f.has_safety_comment(site.line) || f.allowed(SAFETY_COMMENT, site.line) {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line: site.line,
            rule: SAFETY_COMMENT,
            message: format!(
                "unsafe {} without a `SAFETY:` comment (or `# Safety` doc section) \
                 attached above it",
                site.kind
            ),
        });
    }
}

// ---------------------------------------------------------------- rule 2

fn check_no_panic_serving(f: &SourceFile, out: &mut Vec<Finding>) {
    if !SERVING_MODULES.contains(&f.path.as_str()) {
        return;
    }
    let toks = &f.toks;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || f.in_test(t.line) {
            continue;
        }
        let hit = match t.text.as_str() {
            // method call: `.unwrap()` / `.expect(`
            "unwrap" | "expect" => {
                i > 0
                    && toks[i - 1].text == "."
                    && toks.get(i + 1).is_some_and(|n| n.text == "(")
            }
            // panicking macro invocation
            "panic" | "unreachable" | "todo" | "unimplemented" => {
                toks.get(i + 1).is_some_and(|n| n.text == "!")
            }
            _ => false,
        };
        if !hit || f.allowed(NO_PANIC_SERVING, t.line) {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line: t.line,
            rule: NO_PANIC_SERVING,
            message: format!(
                "`{}` on the serving path — return an Err, or justify with a \
                 lint:allow directive carrying a reason",
                t.text
            ),
        });
    }
}

// ---------------------------------------------------------------- rule 3

fn check_lock_poison(f: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &f.toks;
    for i in 1..toks.len() {
        if toks[i].kind != TokKind::Ident || toks[i].text != "lock" {
            continue;
        }
        let bare = toks[i - 1].text == "."
            && toks.get(i + 1).is_some_and(|t| t.text == "(")
            && toks.get(i + 2).is_some_and(|t| t.text == ")")
            && toks.get(i + 3).is_some_and(|t| t.text == ".")
            && toks
                .get(i + 4)
                .is_some_and(|t| t.text == "unwrap" || t.text == "expect");
        let line = toks[i].line;
        if !bare || f.in_test(line) || f.allowed(LOCK_POISON, line) {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line,
            rule: LOCK_POISON,
            message: "bare `.lock().unwrap()` — recover from poisoning with \
                      `.unwrap_or_else(|e| e.into_inner())` (threadpool::relock is \
                      the canonical helper)"
                .to_string(),
        });
    }
}

// ---------------------------------------------------------------- rule 4

fn check_target_feature(f: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &f.toks;
    let in_dispatch_module = f.path.ends_with("src/quant/kernel.rs");
    let mut any_attr = false;
    let mut i = 0;
    while i + 2 < toks.len() {
        let is_attr = toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "target_feature";
        if !is_attr {
            i += 1;
            continue;
        }
        any_attr = true;
        let attr_line = toks[i].line;
        let mut j = skip_attr(toks, i + 1);
        // scan the item header (skipping further attributes) for `unsafe`
        // before `fn`
        let mut saw_unsafe = false;
        let mut saw_fn = false;
        let mut guard = 0;
        while j < toks.len() && guard < 48 {
            if toks[j].text == "#" && toks.get(j + 1).is_some_and(|t| t.text == "[") {
                j = skip_attr(toks, j + 1);
                guard += 1;
                continue;
            }
            if toks[j].kind == TokKind::Ident {
                match toks[j].text.as_str() {
                    "unsafe" => saw_unsafe = true,
                    "fn" => {
                        saw_fn = true;
                        break;
                    }
                    _ => {}
                }
            }
            j += 1;
            guard += 1;
        }
        if saw_fn && !saw_unsafe && !f.allowed(TARGET_FEATURE_UNSAFE, attr_line) {
            out.push(Finding {
                file: f.path.clone(),
                line: attr_line,
                rule: TARGET_FEATURE_UNSAFE,
                message: "#[target_feature] function must be declared `unsafe` — callers \
                          must prove the CPU support the attribute assumes"
                    .to_string(),
            });
        }
        if !in_dispatch_module && !f.allowed(TARGET_FEATURE_UNSAFE, attr_line) {
            out.push(Finding {
                file: f.path.clone(),
                line: attr_line,
                rule: TARGET_FEATURE_UNSAFE,
                message: "#[target_feature] outside rust/src/quant/kernel.rs — feature-gated \
                          code must stay behind the runtime-detection dispatch there"
                    .to_string(),
            });
        }
        i = j + 1;
    }
    if any_attr && in_dispatch_module {
        let has_detect = toks.iter().any(|t| {
            t.kind == TokKind::Ident
                && (t.text == "is_x86_feature_detected" || t.text == "is_aarch64_feature_detected")
        });
        if !has_detect {
            out.push(Finding {
                file: f.path.clone(),
                line: 1,
                rule: TARGET_FEATURE_UNSAFE,
                message: "dispatch module declares #[target_feature] fns but never calls a \
                          runtime feature-detection macro"
                    .to_string(),
            });
        }
    }
}

/// `toks[open_idx]` is the `[` of an attribute; return the index just
/// past its matching `]`.
fn skip_attr(toks: &[crate::lint::source::Tok], open_idx: usize) -> usize {
    let mut depth = 0usize;
    let mut j = open_idx;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    j
}

// ---------------------------------------------------------------- rule 5

struct CanonicalFields {
    fields: Vec<String>,
    file: String,
    line: usize,
}

/// Pull the canonical STATS field order out of `Metrics::snapshot`'s
/// `fields: vec![("name", value), ..]` literal.
fn extract_canonical_fields(f: &SourceFile) -> Option<CanonicalFields> {
    let toks = &f.toks;
    for i in 0..toks.len().saturating_sub(4) {
        let hit = toks[i].text == "fields"
            && toks[i + 1].text == ":"
            && toks[i + 2].text == "vec"
            && toks[i + 3].text == "!"
            && toks[i + 4].text == "[";
        if !hit {
            continue;
        }
        let mut fields = Vec::new();
        let mut depth = 0i32;
        let mut j = i + 4;
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "[" => depth += 1,
                    "]" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
            }
            // a tuple key: string right after `(` that follows `[` or `,`
            if t.kind == TokKind::Str
                && j >= 2
                && toks[j - 1].text == "("
                && matches!(toks[j - 2].text.as_str(), "[" | ",")
            {
                fields.push(t.text.clone());
            }
            j += 1;
        }
        if fields.is_empty() {
            return None;
        }
        return Some(CanonicalFields {
            fields,
            file: f.path.clone(),
            line: toks[i].line,
        });
    }
    None
}

/// The canonical list itself must keep `resident_bytes` last (bench
/// parsers rsplit on it) and the kv page counters ahead of `threads`.
fn check_canonical_shape(canon: &CanonicalFields, out: &mut Vec<Finding>) {
    if canon.fields.last().map(|s| s.as_str()) != Some("resident_bytes") {
        out.push(Finding {
            file: canon.file.clone(),
            line: canon.line,
            rule: STATS_WIRE_ORDER,
            message: "`resident_bytes` must be the last snapshot field — parsers split it \
                      off the line tail"
                .to_string(),
        });
    }
    let threads_at = canon.fields.iter().position(|s| s == "threads");
    if let Some(ti) = threads_at {
        for (i, name) in canon.fields.iter().enumerate() {
            if name.starts_with("kv_") && i > ti {
                out.push(Finding {
                    file: canon.file.clone(),
                    line: canon.line,
                    rule: STATS_WIRE_ORDER,
                    message: format!("kv page counter `{name}` must precede `threads=` in the \
                                      snapshot field order"),
                });
            }
        }
    }
}

/// Position of `name=` in `line` as a standalone field key (the
/// preceding char must not extend an identifier, so `kv_quant=` never
/// matches inside `kv_quantized=`).
fn find_field(line: &str, name: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = line[from..].find(name) {
        let at = from + rel;
        let end = at + name.len();
        let prev_ok = at == 0 || {
            let p = bytes[at - 1];
            !(p.is_ascii_alphanumeric() || p == b'_')
        };
        if prev_ok && bytes.get(end) == Some(&b'=') {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// The coordinator rustdoc's STATS reply row must list every canonical
/// field, in canonical order — docs drift silently otherwise.
fn check_stats_doc_table(f: &SourceFile, canon: &[String], out: &mut Vec<Finding>) {
    let doc = f
        .comments
        .iter()
        .find(|(_, text)| text.contains("STATS") && text.contains("requests="));
    let (&line, text) = match doc {
        Some(d) => d,
        None => {
            out.push(Finding {
                file: f.path.clone(),
                line: 1,
                rule: STATS_WIRE_ORDER,
                message: "no rustdoc line documents the STATS reply fields (expected a doc \
                          row mentioning STATS with the field list)"
                    .to_string(),
            });
            return;
        }
    };
    let mut last: Option<(usize, &str)> = None;
    for name in canon {
        match find_field(text, name) {
            Some(p) => {
                if let Some((lp, lname)) = last {
                    if p < lp {
                        out.push(Finding {
                            file: f.path.clone(),
                            line,
                            rule: STATS_WIRE_ORDER,
                            message: format!(
                                "STATS doc lists `{name}` before `{lname}` — out of snapshot \
                                 order"
                            ),
                        });
                        return;
                    }
                }
                last = Some((p, name));
            }
            None => {
                out.push(Finding {
                    file: f.path.clone(),
                    line,
                    rule: STATS_WIRE_ORDER,
                    message: format!("STATS doc row is missing snapshot field `{name}`"),
                });
                return;
            }
        }
    }
}

/// Any line (code, doc, or literal) quoting three or more snapshot
/// fields must quote them in canonical order — this is what keeps the
/// sim dump, serve_tcp rustdoc, transcripts, and bench parsers agreeing.
fn check_field_order_lines(f: &SourceFile, canon: &[String], out: &mut Vec<Finding>) {
    for (idx, line) in f.lines.iter().enumerate() {
        let mut present: Vec<(usize, usize)> = canon
            .iter()
            .enumerate()
            .filter_map(|(ci, name)| find_field(line, name).map(|pos| (pos, ci)))
            .collect();
        if present.len() < 3 {
            continue;
        }
        present.sort_unstable();
        let lno = idx + 1;
        if f.allowed(STATS_WIRE_ORDER, lno) {
            continue;
        }
        for w in present.windows(2) {
            if w[0].1 >= w[1].1 {
                out.push(Finding {
                    file: f.path.clone(),
                    line: lno,
                    rule: STATS_WIRE_ORDER,
                    message: format!(
                        "`{}` quoted before `{}` — snapshot fields must appear in \
                         Metrics::snapshot order wherever three or more are named",
                        canon[w[1].1], canon[w[0].1]
                    ),
                });
                break;
            }
        }
    }
}

/// `write!`/`writeln!` replies must lead with a known wire verb: a typo'd
/// or invented verb would silently break every client parser.
fn check_reply_verbs(f: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &f.toks;
    for i in 0..toks.len() {
        let is_write = toks[i].kind == TokKind::Ident
            && (toks[i].text == "write" || toks[i].text == "writeln")
            && toks.get(i + 1).is_some_and(|t| t.text == "!")
            && toks.get(i + 2).is_some_and(|t| t.text == "(");
        if !is_write {
            continue;
        }
        let mut depth = 0i32;
        let mut j = i + 2;
        let limit = (i + 32).min(toks.len());
        while j < limit {
            match (toks[j].kind, toks[j].text.as_str()) {
                (TokKind::Punct, "(") => depth += 1,
                (TokKind::Punct, ")") => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                (TokKind::Str, _) if depth == 1 => {
                    let line = toks[j].line;
                    if let Some(verb) = leading_caps_word(&toks[j].text) {
                        if !WIRE_VERBS.contains(&verb)
                            && !f.in_test(line)
                            && !f.allowed(STATS_WIRE_ORDER, line)
                        {
                            out.push(Finding {
                                file: f.path.clone(),
                                line,
                                rule: STATS_WIRE_ORDER,
                                message: format!(
                                    "reply leads with `{verb}`, which is not a wire-protocol \
                                     verb — clients key on the first word"
                                ),
                            });
                        }
                    }
                    break;
                }
                _ => {}
            }
            j += 1;
        }
    }
}

/// Leading run of 2+ uppercase ASCII letters terminated by end, space, or
/// a format placeholder.
fn leading_caps_word(s: &str) -> Option<&str> {
    let end = s
        .find(|c: char| !c.is_ascii_uppercase())
        .unwrap_or(s.len());
    if end < 2 {
        return None;
    }
    match s[end..].chars().next() {
        None | Some(' ') | Some('{') => Some(&s[..end]),
        _ => None,
    }
}

/// The coordinator must keep recognizing every request verb literally.
fn check_request_verbs_present(f: &SourceFile, out: &mut Vec<Finding>) {
    for verb in REQUEST_VERBS {
        let present = f.toks.iter().any(|t| {
            t.kind == TokKind::Str
                && (t.text == *verb || t.text.strip_prefix(verb).is_some_and(|r| r.starts_with(' ')))
        });
        if !present {
            out.push(Finding {
                file: f.path.clone(),
                line: 1,
                rule: STATS_WIRE_ORDER,
                message: format!(
                    "request verb `{verb}` no longer appears as a string literal — the \
                     wire protocol must keep accepting it"
                ),
            });
        }
    }
}

/// The committed trace format keeps its lowercase event verbs.
fn check_trace_verbs_present(f: &SourceFile, out: &mut Vec<Finding>) {
    for verb in TRACE_VERBS {
        let present = f
            .toks
            .iter()
            .any(|t| t.kind == TokKind::Str && t.text == *verb);
        if !present {
            out.push(Finding {
                file: f.path.clone(),
                line: 1,
                rule: STATS_WIRE_ORDER,
                message: format!(
                    "trace event verb `{verb}` no longer appears as a string literal — \
                     committed .trace files would stop replaying"
                ),
            });
        }
    }
}

// ----------------------------------------------------------- meta rule

fn check_allow_syntax(f: &SourceFile, out: &mut Vec<Finding>) {
    for a in &f.allows {
        let message = if a.malformed {
            "unterminated lint:allow directive — expected `(<rule>): <reason>`".to_string()
        } else if !known_rule(&a.rule) {
            format!("lint:allow names unknown rule `{}`", a.rule)
        } else if a.reason.len() < 3 {
            format!(
                "lint:allow({}) carries no reason — say why the exception is sound",
                a.rule
            )
        } else {
            continue;
        };
        out.push(Finding {
            file: f.path.clone(),
            line: a.comment_line,
            rule: ALLOW_SYNTAX,
            message,
        });
    }
}
