//! Minimal Rust source scanner backing the repo-native lint engine.
//!
//! Deliberately not a parser: a lossy token stream (identifiers,
//! punctuation, string/char/number literals, lifetimes) plus the comment
//! list and a handful of line classifications — code lines, attribute
//! lines, `#[cfg(test)]` regions — is enough for every rule in
//! [`crate::lint::rules`], and keeps the whole pass dependency-free. The
//! scanner is honest about what it is: rules match token shapes and line
//! patterns, not semantics, which is exactly the granularity the repo's
//! conventions (`// SAFETY:`, poison-recovering locks, wire-literal
//! ordering) are written at.

use std::collections::BTreeMap;

/// Token classes the rules discriminate on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// String literal (normal / raw / byte). `text` holds the content
    /// between the quotes, escape sequences left as written.
    Str,
    /// Char or number literal; the content is irrelevant to every rule.
    Lit,
    /// A lifetime such as `'a` (`text` excludes the tick).
    Lifetime,
    /// A single punctuation character.
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line the token starts on.
    pub line: usize,
}

/// A parsed `lint:allow` escape hatch: `// lint:allow` followed by
/// `(<rule>): <reason>`. (Spelled out indirectly here so this very doc
/// comment is not itself parsed as a directive.)
#[derive(Debug, Clone)]
pub struct AllowDirective {
    pub rule: String,
    pub reason: String,
    /// Line the directive governs: the comment's own line when it trails a
    /// statement, otherwise the next code line below it.
    pub target_line: usize,
    /// Line the comment itself sits on (for reporting).
    pub comment_line: usize,
    /// The `(` had no matching `)` — reported by the allow-syntax rule.
    pub malformed: bool,
}

/// One scanned source file: raw lines, token stream, comments, and the
/// line classifications the rules consume.
pub struct SourceFile {
    /// Repo-relative path with forward slashes (e.g. `rust/src/lib.rs`).
    pub path: String,
    pub lines: Vec<String>,
    pub toks: Vec<Tok>,
    /// Line → concatenated comment text on that line (markers stripped,
    /// trimmed). Doc comments keep their extra `/` or `!` as leading text.
    pub comments: BTreeMap<usize, String>,
    /// Whole file is test/bench/example context (path under `rust/tests`,
    /// `rust/benches`, or `examples`).
    pub is_test_file: bool,
    pub allows: Vec<AllowDirective>,
    code: Vec<bool>,
    attr: Vec<bool>,
    test: Vec<bool>,
}

/// A site the `safety-comment` rule must find a justification for.
#[derive(Debug, Clone, Copy)]
pub struct UnsafeSite {
    pub line: usize,
    pub kind: &'static str,
}

impl SourceFile {
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let lines: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        let nlines = lines.len();
        let (toks, comments) = lex(text);

        let mut code = vec![false; nlines + 2];
        let mut attr = vec![false; nlines + 2];
        let mut first_on_line: BTreeMap<usize, usize> = BTreeMap::new();
        for (i, t) in toks.iter().enumerate() {
            if t.line < code.len() {
                code[t.line] = true;
            }
            first_on_line.entry(t.line).or_insert(i);
        }
        for (&line, &i) in &first_on_line {
            if toks[i].kind == TokKind::Punct && toks[i].text == "#" {
                attr[line] = true;
            }
        }

        let test = test_regions(&toks, nlines);
        let is_test_file = {
            let p = path.replace('\\', "/");
            p.starts_with("rust/tests/")
                || p.starts_with("rust/benches/")
                || p.starts_with("examples/")
        };

        let mut f = SourceFile {
            path: path.replace('\\', "/"),
            lines,
            toks,
            comments,
            is_test_file,
            allows: Vec::new(),
            code,
            attr,
            test,
        };
        f.allows = f.parse_allows();
        f
    }

    /// True when any token starts on `line` (1-based).
    pub fn is_code_line(&self, line: usize) -> bool {
        self.code.get(line).copied().unwrap_or(false)
    }

    /// True when the first token on `line` is `#` (an attribute).
    pub fn is_attr_line(&self, line: usize) -> bool {
        self.attr.get(line).copied().unwrap_or(false)
    }

    /// True inside a `#[cfg(test)]` item or anywhere in a test/bench/
    /// example file.
    pub fn in_test(&self, line: usize) -> bool {
        self.is_test_file || self.test.get(line).copied().unwrap_or(false)
    }

    pub fn comment_on(&self, line: usize) -> Option<&str> {
        self.comments.get(&line).map(|s| s.as_str())
    }

    /// A valid `lint:allow` directive for `rule` (known shape,
    /// non-trivial reason) governs `line`. Malformed directives never
    /// suppress — they are themselves findings of the allow-syntax rule.
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows.iter().any(|a| {
            a.rule == rule && a.target_line == line && !a.malformed && a.reason.len() >= 3
        })
    }

    /// Every `unsafe` introducing a block, fn, impl, trait, or extern
    /// block. `unsafe fn` in *type position* (`call: unsafe fn(..)`,
    /// `as unsafe fn(..)`) is not a site — there is nothing to justify at
    /// a type.
    pub fn unsafe_sites(&self) -> Vec<UnsafeSite> {
        let mut out = Vec::new();
        for (i, t) in self.toks.iter().enumerate() {
            if t.kind != TokKind::Ident || t.text != "unsafe" {
                continue;
            }
            let next = match self.toks.get(i + 1) {
                Some(n) => n,
                None => continue,
            };
            let kind = match (next.kind, next.text.as_str()) {
                (TokKind::Punct, "{") => "block",
                (TokKind::Ident, "fn") => {
                    if self.fn_type_position(i) {
                        continue;
                    }
                    "fn"
                }
                (TokKind::Ident, "extern") => "fn",
                (TokKind::Ident, "impl") => "impl",
                (TokKind::Ident, "trait") => "trait",
                _ => continue,
            };
            out.push(UnsafeSite { line: t.line, kind });
        }
        out
    }

    /// `unsafe fn` preceded by `:`/`(`/`,`/`<`/`=`/`&`/`>` (the tail of
    /// `->`) or `as` is a function-pointer type, not a declaration.
    fn fn_type_position(&self, unsafe_idx: usize) -> bool {
        let prev = match unsafe_idx.checked_sub(1).and_then(|p| self.toks.get(p)) {
            Some(p) => p,
            None => return false,
        };
        match prev.kind {
            TokKind::Punct => {
                matches!(prev.text.as_str(), ":" | "(" | "," | "<" | "=" | "&" | ">" | "|")
            }
            TokKind::Ident => prev.text == "as",
            _ => false,
        }
    }

    /// Walk the comment block attached to `site_line` (same line, or
    /// upward over comment and attribute lines) looking for a `SAFETY:`
    /// marker or a rustdoc `# Safety` section. A blank or plain-code line
    /// breaks the association.
    pub fn has_safety_comment(&self, site_line: usize) -> bool {
        let is_safety =
            |c: &str| c.contains("SAFETY:") || c.contains("SAFETY(") || c.contains("# Safety");
        if self.comment_on(site_line).is_some_and(is_safety) {
            return true;
        }
        let mut l = site_line;
        while l > 1 {
            l -= 1;
            let comment = self.comment_on(l);
            let code = self.is_code_line(l);
            if let Some(c) = comment {
                if is_safety(c) {
                    return true;
                }
                if !code {
                    continue; // comment-only line: keep walking the block
                }
                return false; // trailing comment of the statement above
            }
            if self.is_attr_line(l) {
                continue; // attributes sit between the comment and the item
            }
            return false;
        }
        false
    }

    fn parse_allows(&self) -> Vec<AllowDirective> {
        let mut out = Vec::new();
        for (&line, text) in &self.comments {
            let mut rest = text.as_str();
            while let Some(pos) = rest.find("lint:allow(") {
                let after = &rest[pos + "lint:allow(".len()..];
                let (rule, reason, malformed) = match after.find(')') {
                    Some(close) => {
                        let reason = after[close + 1..]
                            .trim_start_matches([':', '-', ' '])
                            .trim()
                            .to_string();
                        (after[..close].trim().to_string(), reason, false)
                    }
                    None => (after.trim().to_string(), String::new(), true),
                };
                let target_line = if self.is_code_line(line) {
                    line
                } else {
                    // governs the next code line below the comment
                    (line + 1..self.lines.len() + 1)
                        .find(|&l| self.is_code_line(l))
                        .unwrap_or(line)
                };
                out.push(AllowDirective {
                    rule,
                    reason,
                    target_line,
                    comment_line: line,
                    malformed,
                });
                rest = after;
            }
        }
        out
    }
}

/// Mark the line span of every item annotated `#[cfg(test)]`: from the
/// attribute to the matching close brace of the item body (or the
/// terminating `;` for brace-less items).
fn test_regions(toks: &[Tok], nlines: usize) -> Vec<bool> {
    let mut test = vec![false; nlines + 2];
    let pat = ["#", "[", "cfg", "(", "test", ")", "]"];
    let mut i = 0;
    while i + pat.len() <= toks.len() {
        let hit = toks[i..i + pat.len()]
            .iter()
            .zip(pat.iter())
            .all(|(t, p)| t.text == *p);
        if !hit {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + pat.len();
        let mut depth = 0usize;
        let mut end_line = start_line;
        while let Some(t) = toks.get(j) {
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth = depth.saturating_sub(1);
                        if depth == 0 {
                            end_line = t.line;
                            break;
                        }
                    }
                    ";" if depth == 0 => {
                        end_line = t.line;
                        break;
                    }
                    _ => {}
                }
            }
            end_line = t.line;
            j += 1;
        }
        for l in start_line..=end_line.min(nlines) {
            test[l] = true;
        }
        i = j + 1;
    }
    test
}

/// Tokenize `text`. Returns the token stream plus a map of line →
/// comment text (line and block comments; block comments are recorded on
/// their first line).
fn lex(text: &str) -> (Vec<Tok>, BTreeMap<usize, String>) {
    let cs: Vec<char> = text.chars().collect();
    let n = cs.len();
    let mut toks = Vec::new();
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut push_comment = |line: usize, body: &str| {
        let body = body.trim();
        let slot = comments.entry(line).or_default();
        if !slot.is_empty() {
            slot.push(' ');
        }
        slot.push_str(body);
    };
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            let body: String = cs[start..j].iter().collect();
            push_comment(line, &body);
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let first = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut body = String::new();
            while j < n && depth > 0 {
                if cs[j] == '/' && cs.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                    continue;
                }
                if cs[j] == '*' && cs.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                body.push(cs[j]);
                j += 1;
            }
            push_comment(first, &body);
            i = j;
            continue;
        }
        // string literals, including r"", r#""#, b"", br#""#
        if c == '"' {
            let (tok, ni, nl) = lex_string(&cs, i, line);
            toks.push(tok);
            i = ni;
            line = nl;
            continue;
        }
        if (c == 'r' || c == 'b') && is_string_start(&cs, i) {
            let (tok, ni, nl) = lex_prefixed_string(&cs, i, line);
            toks.push(tok);
            i = ni;
            line = nl;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            let (tok, ni) = lex_tick(&cs, i, line);
            if let Some(t) = tok {
                toks.push(t);
            }
            i = ni;
            continue;
        }
        if c == '_' || c.is_ascii_alphabetic() {
            let mut j = i + 1;
            while j < n && (cs[j] == '_' || cs[j].is_ascii_alphanumeric()) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: cs[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            // loose: digits, type suffixes, hex, underscores, one decimal dot
            let mut j = i + 1;
            while j < n {
                if cs[j] == '_' || cs[j].is_ascii_alphanumeric() {
                    j += 1;
                } else if cs[j] == '.' && cs.get(j + 1).is_some_and(|d| d.is_ascii_digit()) {
                    j += 2;
                } else {
                    break;
                }
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                text: cs[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    (toks, comments)
}

/// `r` or `b` at `i` starts a string literal (`r"`, `r#`, `b"`, `br"`,
/// `br#`) rather than an identifier.
fn is_string_start(cs: &[char], i: usize) -> bool {
    match cs[i] {
        'r' => matches!(cs.get(i + 1), Some('"') | Some('#')),
        'b' => match cs.get(i + 1) {
            Some('"') => true,
            Some('r') => matches!(cs.get(i + 2), Some('"') | Some('#')),
            _ => false,
        },
        _ => false,
    }
}

/// Normal (escaped) string starting at the `"` in `cs[i]`.
fn lex_string(cs: &[char], i: usize, mut line: usize) -> (Tok, usize, usize) {
    let start_line = line;
    let mut j = i + 1;
    let mut body = String::new();
    while j < cs.len() {
        match cs[j] {
            '\\' => {
                body.push(cs[j]);
                if let Some(&e) = cs.get(j + 1) {
                    body.push(e);
                    if e == '\n' {
                        line += 1;
                    }
                }
                j += 2;
            }
            '"' => {
                j += 1;
                break;
            }
            ch => {
                if ch == '\n' {
                    line += 1;
                }
                body.push(ch);
                j += 1;
            }
        }
    }
    (
        Tok {
            kind: TokKind::Str,
            text: body,
            line: start_line,
        },
        j,
        line,
    )
}

/// Raw / byte string starting at the `r`/`b` prefix in `cs[i]`.
fn lex_prefixed_string(cs: &[char], i: usize, mut line: usize) -> (Tok, usize, usize) {
    let mut j = i;
    let mut raw = false;
    while j < cs.len() && (cs[j] == 'r' || cs[j] == 'b') {
        raw |= cs[j] == 'r';
        j += 1;
    }
    if !raw {
        // plain byte string b"..." — escaped like a normal string
        let (mut tok, ni, nl) = lex_string(cs, j, line);
        tok.line = line;
        return (tok, ni, nl);
    }
    let mut hashes = 0usize;
    while cs.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    let start_line = line;
    let mut body = String::new();
    j += 1; // opening quote
    while j < cs.len() {
        if cs[j] == '"' {
            let mut k = 0;
            while k < hashes && cs.get(j + 1 + k) == Some(&'#') {
                k += 1;
            }
            if k == hashes {
                j += 1 + hashes;
                break;
            }
        }
        if cs[j] == '\n' {
            line += 1;
        }
        body.push(cs[j]);
        j += 1;
    }
    (
        Tok {
            kind: TokKind::Str,
            text: body,
            line: start_line,
        },
        j,
        line,
    )
}

/// `'` at `cs[i]`: either a char literal (skipped as [`TokKind::Lit`]) or
/// a lifetime token.
fn lex_tick(cs: &[char], i: usize, line: usize) -> (Option<Tok>, usize) {
    let lit = |j: usize| {
        (
            Some(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line,
            }),
            j,
        )
    };
    match cs.get(i + 1) {
        Some('\\') => {
            // escaped char: scan to the closing tick
            let mut j = i + 2;
            while j < cs.len() && cs[j] != '\'' {
                if cs[j] == '\\' {
                    j += 1;
                }
                j += 1;
            }
            lit(j + 1)
        }
        Some(&c) if c == '_' || c.is_ascii_alphabetic() => {
            let mut j = i + 2;
            while j < cs.len() && (cs[j] == '_' || cs[j].is_ascii_alphanumeric()) {
                j += 1;
            }
            if cs.get(j) == Some(&'\'') {
                lit(j + 1) // 'a'
            } else {
                let t = Tok {
                    kind: TokKind::Lifetime,
                    text: cs[i + 1..j].iter().collect(),
                    line,
                };
                (Some(t), j)
            }
        }
        Some(_) => {
            // char like '(' or '0': tick, one char, tick
            if cs.get(i + 2) == Some(&'\'') {
                lit(i + 3)
            } else {
                lit(i + 1)
            }
        }
        None => (None, i + 1),
    }
}
