//! Quickstart: codebook-free Leech lattice quantization in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the public API end to end: build the indexer, quantize a Gaussian
//! block both ways (spherical shaping and shape–gain), inspect the compact
//! integer codes, dequantize, and measure distortion — no codebook is ever
//! materialized.

use std::sync::Arc;

use llvq::leech::index::LeechIndexer;
use llvq::quant::llvq::{LlvqShapeGain, LlvqSpherical};
use llvq::quant::VectorQuantizer;
use llvq::util::rng::Xoshiro256pp;

fn main() {
    // Λ24(13): 2.8·10¹⁴ lattice points, indexed in 48 bits = 2.0 bits/weight.
    println!("building Λ24(M=13) indexer (codebook-free: ~2 MB of tables)…");
    let ix = Arc::new(LeechIndexer::new(13));
    println!(
        "  {} points, {} bits/block, {:.3} bits/weight\n",
        ix.num_points(),
        ix.index_bits(),
        ix.bits_per_dim()
    );

    let spherical = LlvqSpherical::new(ix.clone());
    let shape_gain = LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1);

    let mut rng = Xoshiro256pp::new(42);
    let mut x = [0f32; 24];
    rng.fill_gaussian_f32(&mut x);
    println!("input block  : {:?}\n", &x[..6]);

    for q in [&spherical as &dyn VectorQuantizer, &shape_gain] {
        let code = q.quantize(&x);
        let mut y = [0f32; 24];
        q.dequantize(&code, &mut y);
        let mse: f64 = x
            .iter()
            .zip(&y)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / 24.0;
        println!("{}", q.name());
        println!("  code words : {:?} ({} bits)", code.words, code.bits);
        println!("  reconstruct: {:?}", &y[..6]);
        println!("  block MSE  : {mse:.5}\n");
    }

    // aggregate rate–distortion on 500 blocks
    let (mse, bits) = llvq::quant::gaussian_rd(&shape_gain, 500, 7);
    let sqnr = llvq::math::stats::sqnr_bits(mse);
    println!(
        "500-block Gaussian check [shape-gain]: {:.3} bits/weight, MSE {:.4}, \
         SQNR {:.3} bits, retention {:.1}% of Shannon",
        bits,
        mse,
        sqnr,
        llvq::math::stats::retention_pct(sqnr, bits)
    );
}
