//! Serving example: quantize a model to a packed `.llvqm`, pick an
//! execution backend (dense / cached / fused), serve batched requests plus
//! a streamed generation session, and report latency/throughput plus
//! resident weight bytes — the paper's "no expensive lookups on the
//! inference path" claim as a serving demo: the fused backend answers
//! every request straight from the bit-packed code streams, never
//! materializing dense f32.
//!
//! ```bash
//! cargo run --release --example serve_quantized -- --requests 200 --backend fused
//! ```
//!
//! The same engine speaks the TCP line protocol via `llvq serve`:
//!
//! **v1 (stateless):** `NEXT t1,t2,…` → `OK next=<argmax> logit=<v>`;
//! `STATS`; `QUIT`.
//!
//! **v2 (generation sessions, one per connection):** `OPEN` →
//! `OK session=<id>`; `FEED t1,t2,…` queues the prompt for chunked
//! prefill and returns immediately → `QUEUED <n>` (the tokens drain at
//! `--prefill-chunk` per scheduler tick, interleaved with other sessions'
//! decode steps, so a long prompt never stalls active generations);
//! `GEN <n> [temp=…] [topk=…] [seed=…]` waits for the session's prefill
//! to drain, then streams `TOK <id>` per sampled token and finishes with
//! `OK generated=<n> len=<total>`; `CLOSE` → `OK closed len=<total>`.
//! Greedy `GEN n` (the `temp=0` default) is bit-identical to `n` `NEXT`
//! calls with the growing prefix — chunked prefill itself is bit-identical
//! to one-shot prefill. Example transcript:
//!
//! ```text
//! > OPEN
//! < OK session=1
//! > FEED 5,6,7,8
//! < QUEUED 4
//! > GEN 3 temp=0.8 topk=8 seed=42
//! < TOK 17
//! < TOK 3
//! < TOK 44
//! < OK generated=3 len=7
//! > CLOSE
//! < OK closed len=7
//! ```

use std::sync::Arc;
use std::time::Instant;

use llvq::coordinator::{BackendEngine, BatchForward, BatcherConfig, Coordinator, GenEvent};
use llvq::model::sample::SampleParams;
use llvq::experiments::load_model;
use llvq::leech::index::LeechIndexer;
use llvq::model::backend::{BackendKind, ExecutionBackend};
use llvq::model::config::config_by_name;
use llvq::model::corpus::Corpus;
use llvq::model::packed::PackedFile;
use llvq::pipeline::driver::{quantize_model_packed, PtqOptions};
use llvq::quant::llvq::LlvqShapeGain;
use llvq::util::cli::Args;

fn main() {
    let a = Args::new("serve_quantized — batched serving benchmark")
        .flag("model", "llama2-tiny", "zoo model name")
        .flag("backend", "dense", "dense|cached|fused execution backend")
        .flag("requests", "200", "total requests to issue")
        .flag("clients", "16", "concurrent client threads")
        .flag("max-batch", "8", "dynamic batch limit")
        .flag("max-wait-ms", "2", "batch window")
        .switch("allow-random", "use random weights if artifacts missing")
        .switch("skip-quantize", "serve fp32 weights directly (dense only)")
        .parse(std::env::args().skip(1))
        .unwrap();

    let cfg = config_by_name(&a.get("model").unwrap()).expect("unknown model");
    let kind = BackendKind::parse(&a.get("backend").unwrap()).expect("dense|cached|fused");
    let w = match load_model(&cfg, a.get_bool("allow-random")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    };

    // kept alive while a cached backend serves (layers fault in from it);
    // removed on exit
    let mut temp_artifact: Option<std::path::PathBuf> = None;
    let backend = if a.get_bool("skip-quantize") {
        assert!(
            kind == BackendKind::Dense,
            "--skip-quantize serves fp32; packed backends need a quantized artifact"
        );
        ExecutionBackend::dense(w)
    } else {
        println!("quantizing {} at 2 bits/weight before serving …", cfg.name);
        let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1);
        let art = quantize_model_packed(&w, &q, &PtqOptions::default());
        println!("  {:.4} bits/weight", art.report.bits_per_weight());
        match kind {
            BackendKind::Dense => ExecutionBackend::dense(art.weights),
            _ => {
                // packed backends execute from the artifact itself
                let path = std::env::temp_dir().join(format!(
                    "llvq-serve-quantized-{}.llvqm",
                    std::process::id()
                ));
                art.packed.save(&path).expect("write artifact");
                let f = PackedFile::open(&path).expect("open artifact");
                let threads = llvq::util::threadpool::default_threads();
                let be = match kind {
                    BackendKind::Cached => ExecutionBackend::packed_cached(f, threads),
                    _ => ExecutionBackend::packed_fused(f, threads),
                }
                .expect("build backend");
                if kind == BackendKind::Fused {
                    // fused read all its code streams up front; cached
                    // still faults layers in from the file on first touch
                    std::fs::remove_file(&path).ok();
                } else {
                    temp_artifact = Some(path);
                }
                be
            }
        }
    };
    println!(
        "backend: {} | resident weight bytes at start: {}",
        backend.kind().label(),
        backend.resident_weight_bytes()
    );

    let engine = Arc::new(BackendEngine::new(backend));
    let coord = Coordinator::start(
        engine.clone(),
        BatcherConfig {
            max_batch: a.get_usize("max-batch"),
            max_wait: std::time::Duration::from_millis(a.get_u64("max-wait-ms")),
            ..Default::default()
        },
    );

    let total = a.get_usize("requests");
    let clients = a.get_usize("clients");
    println!("issuing {total} requests from {clients} clients …");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let coord = coord.clone();
            let per = total / clients;
            s.spawn(move || {
                let mut corpus = Corpus::new(3000 + c as u64);
                for _ in 0..per {
                    let (toks, _) = corpus.generate(32);
                    let logits = coord.submit(toks).expect("request failed");
                    assert_eq!(logits.len(), 64);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let served = coord.metrics.requests.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "served {served} requests in {wall:.2}s → {:.1} req/s | mean batch {:.2} | \
         mean latency {:.2} ms | resident weight bytes now: {}",
        served as f64 / wall,
        coord.metrics.mean_batch(),
        coord.metrics.mean_latency_ms(),
        engine.resident_weight_bytes()
    );

    // ---- generation session demo (the v2 OPEN/FEED/GEN/CLOSE path) ----
    let gen_n = 12usize;
    let sid = coord.open_session().expect("open session");
    // FEED queues the prompt and returns at once; the GEN below implicitly
    // waits for the chunked prefill to drain
    let fed = coord.feed(sid, vec![5, 6, 7, 8]).expect("queue prompt");
    let events = coord
        .generate(
            sid,
            gen_n,
            SampleParams {
                temperature: 0.8,
                top_k: 8,
                seed: 42,
            },
        )
        .expect("start generation");
    let tg = Instant::now();
    let mut generated: Vec<u8> = Vec::new();
    loop {
        match events.recv().expect("generation stream") {
            Ok(GenEvent::Token(t)) => generated.push(t),
            Ok(GenEvent::Done { len }) => {
                let secs = tg.elapsed().as_secs_f64();
                let rendered: Vec<String> =
                    generated.iter().map(|t| t.to_string()).collect();
                println!(
                    "session {sid}: queued {fed} prompt tokens, generated {} \
                     (len {len}) in {:.1} ms → {:.1} tok/s: {}",
                    generated.len(),
                    secs * 1e3,
                    generated.len() as f64 / secs.max(1e-9),
                    rendered.join(",")
                );
                break;
            }
            Err(e) => {
                eprintln!("generation failed: {e}");
                break;
            }
        }
    }
    coord.close_session(sid).expect("close session");
    coord.stop();
    if let Some(p) = temp_artifact {
        std::fs::remove_file(p).ok();
    }
}
