//! Serving example: quantize a model, serve batched requests, report
//! latency/throughput (the paper-adjacent serving claim: the dequantized
//! model costs the same to serve regardless of quantizer, and the batching
//! coordinator keeps the engine saturated).
//!
//! ```bash
//! cargo run --release --example serve_quantized -- --requests 200
//! ```

use std::sync::Arc;
use std::time::Instant;

use llvq::coordinator::{BatcherConfig, Coordinator, NativeEngine};
use llvq::experiments::load_model;
use llvq::leech::index::LeechIndexer;
use llvq::model::config::config_by_name;
use llvq::model::corpus::Corpus;
use llvq::pipeline::driver::{quantize_model, PtqOptions};
use llvq::quant::llvq::LlvqShapeGain;
use llvq::util::cli::Args;

fn main() {
    let a = Args::new("serve_quantized — batched serving benchmark")
        .flag("model", "llama2-tiny", "zoo model name")
        .flag("requests", "200", "total requests to issue")
        .flag("clients", "16", "concurrent client threads")
        .flag("max-batch", "8", "dynamic batch limit")
        .flag("max-wait-ms", "2", "batch window")
        .switch("allow-random", "use random weights if artifacts missing")
        .switch("skip-quantize", "serve fp32 weights directly")
        .parse(std::env::args().skip(1))
        .unwrap();

    let cfg = config_by_name(&a.get("model").unwrap()).expect("unknown model");
    let w = match load_model(&cfg, a.get_bool("allow-random")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    };

    let weights = if a.get_bool("skip-quantize") {
        w
    } else {
        println!("quantizing {} at 2 bits/weight before serving …", cfg.name);
        let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1);
        let (wq, rep) = quantize_model(&w, &q, &PtqOptions::default());
        println!("  {:.4} bits/weight", rep.bits_per_weight());
        wq
    };

    let engine = Arc::new(NativeEngine { weights });
    let coord = Coordinator::start(
        engine,
        BatcherConfig {
            max_batch: a.get_usize("max-batch"),
            max_wait: std::time::Duration::from_millis(a.get_u64("max-wait-ms")),
        },
    );

    let total = a.get_usize("requests");
    let clients = a.get_usize("clients");
    println!("issuing {total} requests from {clients} clients …");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let coord = coord.clone();
            let per = total / clients;
            s.spawn(move || {
                let mut corpus = Corpus::new(3000 + c as u64);
                for _ in 0..per {
                    let (toks, _) = corpus.generate(32);
                    let logits = coord.submit(toks).expect("request failed");
                    assert_eq!(logits.len(), 64);
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let served = coord.metrics.requests.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "served {served} requests in {wall:.2}s → {:.1} req/s | mean batch {:.2} | \
         mean latency {:.2} ms",
        served as f64 / wall,
        coord.metrics.mean_batch(),
        coord.metrics.mean_latency_ms()
    );
    coord.stop();
}
