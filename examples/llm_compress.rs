//! END-TO-END DRIVER (the session contract's flagship example).
//!
//! Exercises every layer of the system on a real small workload:
//!
//!  1. load the JAX-trained tiny LM from `artifacts/` (L2 build output);
//!  2. run the full PTQ pipeline — calibration on the synthetic corpus,
//!     Hadamard rotation, Hessian-corrected 24-dim LLVQ shape–gain
//!     quantization at 2 bits/weight, closed-form scale finetuning (L3);
//!  3. evaluate perplexity + probe accuracies before/after (the paper's
//!     Wiki/MMLU/CSR analogues);
//!  4. if AOT artifacts exist, run the PJRT-compiled forward and the
//!     Pallas dequantization kernel from rust and verify they agree with
//!     the native path (RT + L1);
//!  5. print a compression report.
//!
//! ```bash
//! make artifacts && cargo run --release --example llm_compress
//! ```

use std::sync::Arc;

use llvq::experiments::load_model;
use llvq::leech::index::LeechIndexer;
use llvq::model::config::config_by_name;
use llvq::model::eval::evaluate;
use llvq::pipeline::driver::{quantize_model, PtqOptions};
use llvq::pipeline::rotation::RotationMode;
use llvq::quant::llvq::LlvqShapeGain;
use llvq::quant::VectorQuantizer;
use llvq::util::cli::Args;

fn main() {
    let a = Args::new("llm_compress — end-to-end PTQ of a trained tiny LM")
        .flag("model", "llama2-tiny", "zoo model name")
        .switch("allow-random", "run with random weights if artifacts missing")
        .flag("eval-seqs", "64", "held-out sequences for evaluation")
        .switch("no-finetune", "skip closed-form scale finetuning")
        .parse(std::env::args().skip(1))
        .unwrap();

    let cfg = config_by_name(&a.get("model").unwrap()).expect("unknown model");
    let threads = llvq::util::threadpool::default_threads();
    let seqs = a.get_usize("eval-seqs");

    println!("=== LLVQ end-to-end compression: {} ===", cfg.name);
    let w = match load_model(&cfg, a.get_bool("allow-random")) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    };
    println!(
        "model: {} params ({} in linear layers)",
        cfg.num_params(),
        cfg.num_linear_params()
    );

    // baseline
    let t0 = std::time::Instant::now();
    let base = evaluate(&w, seqs, 2000, threads);
    println!(
        "baseline fp32   : ppl {:.3}  csr* {:.1}%  mmlu* {:.1}%  ({:.1}s eval)",
        base.perplexity,
        base.accuracy_pct,
        base.cloze_pct,
        t0.elapsed().as_secs_f64()
    );

    // quantize: shape–gain M=12 + 1 gain bit = exactly 2 bits/weight
    let q = LlvqShapeGain::new(Arc::new(LeechIndexer::new(12)), 1);
    println!("\nquantizing with {} …", q.name());
    let opts = PtqOptions {
        rotation: RotationMode::InputOutput,
        finetune_scales: !a.get_bool("no-finetune"),
        calib_seqs: 48,
        ..Default::default()
    };
    let tq = std::time::Instant::now();
    let (wq, rep) = quantize_model(&w, &q, &opts);
    println!(
        "quantized {} weights in {:.1}s — {:.4} bits/weight \
         ({}x compression of linear layers)",
        rep.total_params,
        tq.elapsed().as_secs_f64(),
        rep.bits_per_weight(),
        (32.0 / rep.bits_per_weight()).round()
    );

    let quant = evaluate(&wq, seqs, 2000, threads);
    println!(
        "LLVQ 2-bit      : ppl {:.3}  csr* {:.1}%  mmlu* {:.1}%",
        quant.perplexity, quant.accuracy_pct, quant.cloze_pct
    );
    println!(
        "degradation     : Δppl {:+.3} ({:+.1}%), Δcsr* {:+.1} pts",
        quant.perplexity - base.perplexity,
        100.0 * (quant.perplexity - base.perplexity) / base.perplexity,
        quant.accuracy_pct - base.accuracy_pct,
    );

    // PJRT leg: execute the AOT-compiled forward + dequant kernel
    if llvq::runtime::artifacts_available() {
        println!("\n--- PJRT leg (AOT HLO artifacts) ---");
        run_pjrt_leg(&cfg.name);
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the PJRT leg)");
    }
}

#[cfg(pjrt_runtime)]
fn run_pjrt_leg(name: &str) {
    match pjrt_leg(name) {
        Ok(msg) => println!("{msg}"),
        Err(e) => println!("[warn] PJRT leg failed: {e:#}"),
    }
}

/// The offline default build carries no `xla`/`anyhow` dependency; the
/// PJRT leg needs `RUSTFLAGS="--cfg pjrt_runtime"` (see `src/runtime.rs`).
#[cfg(not(pjrt_runtime))]
fn run_pjrt_leg(_name: &str) {
    println!(
        "(PJRT runtime not compiled in — rebuild with \
         RUSTFLAGS=\"--cfg pjrt_runtime\" and the xla/anyhow deps)"
    );
}

#[cfg(pjrt_runtime)]
fn pjrt_leg(name: &str) -> anyhow::Result<String> {
    use llvq::leech::tables::KernelTables;
    use llvq::runtime::{artifact, Runtime};
    let rt = Runtime::cpu()?;
    // dequant kernel smoke: 768 random indices through the compiled kernel
    let cfg_text = std::fs::read_to_string(artifact("config.json"))?;
    let cfg = llvq::util::json::parse(&cfg_text).map_err(anyhow::Error::msg)?;
    let max_m = cfg.path(&["max_m"]).unwrap().as_i64().unwrap() as usize;
    let n = cfg.path(&["dequant_batch"]).unwrap().as_i64().unwrap() as usize;
    let ix = LeechIndexer::new(max_m);
    let t = KernelTables::build(&ix);
    let exe = rt.load(&artifact(&format!("dequant_M{max_m}_N{n}.hlo.txt")))?;
    let mut rng = llvq::util::rng::Xoshiro256pp::new(99);
    let np = t.num_points() as u64;
    let idx: Vec<i64> = (0..n).map(|_| rng.next_range(np) as i64).collect();

    // table literals in aot.py order (same builder as the integration test)
    let mut lits = vec![xla::Literal::vec1(&idx[..]).reshape(&[n as i64])?];
    let g = t.num_groups as i64;
    let v = llvq::leech::tables::MAX_DISTINCT as i64;
    for key in cfg.path(&["table_keys"]).unwrap().as_arr().unwrap() {
        let k = key.as_str().unwrap();
        let lit = match k {
            "group_offsets" => xla::Literal::vec1(&t.group_offsets[..]).reshape(&[g + 1])?,
            "num_codewords" => {
                let d: Vec<i64> = t.num_codewords.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(&d[..]).reshape(&[g])?
            }
            "sign_bits" => {
                let d: Vec<i64> = t.sign_bits.iter().map(|&x| x as i64).collect();
                xla::Literal::vec1(&d[..]).reshape(&[g])?
            }
            "f0_arrangements" => xla::Literal::vec1(&t.f0_arrangements[..]).reshape(&[g])?,
            "f1_arrangements" => xla::Literal::vec1(&t.f1_arrangements[..]).reshape(&[g])?,
            "weight" => xla::Literal::vec1(&t.weight[..]).reshape(&[g])?,
            "cw_base" => xla::Literal::vec1(&t.cw_base[..]).reshape(&[g])?,
            "parity_odd" => xla::Literal::vec1(&t.parity_odd[..]).reshape(&[g])?,
            "f1_neg_parity" => xla::Literal::vec1(&t.f1_neg_parity[..]).reshape(&[g])?,
            "f1_values" => xla::Literal::vec1(&t.f1_values[..]).reshape(&[g, v])?,
            "f1_counts" => xla::Literal::vec1(&t.f1_counts[..]).reshape(&[g, v])?,
            "f0_values" => xla::Literal::vec1(&t.f0_values[..]).reshape(&[g, v])?,
            "f0_counts" => xla::Literal::vec1(&t.f0_counts[..]).reshape(&[g, v])?,
            "golay_sorted" => xla::Literal::vec1(&t.golay_sorted[..]).reshape(&[4096])?,
            other => anyhow::bail!("unknown table key {other}"),
        };
        lits.push(lit);
    }
    let outs = rt.run_literals(&exe, &lits)?;
    let flat: Vec<i32> = outs[0].to_vec()?;
    let mut mism = 0;
    for (i, &index) in idx.iter().enumerate() {
        if flat[i * 24..(i + 1) * 24] != t.dequantize(index as u64)[..] {
            mism += 1;
        }
    }
    anyhow::ensure!(mism == 0, "{mism} kernel mismatches");
    Ok(format!(
        "PJRT dequant kernel ✓ — {n} indices bit-exact vs rust tables \
         (platform: {}, model artifact: lm_forward_{name}_B1)",
        rt.platform()
    ))
}
