//! Rate–distortion sweep on an ideal Gaussian source — regenerates the
//! paper's Figure 1 and Table 4 from the command line.
//!
//! ```bash
//! cargo run --release --example gaussian_rd -- --quick
//! ```

use llvq::experiments::{fig1, table4, Effort};
use llvq::util::cli::Args;

fn main() {
    let a = Args::new("gaussian_rd — Fig. 1 + Table 4 on N(0,1) source")
        .switch("quick", "reduced sample counts")
        .flag("leech-blocks", "", "blocks per Leech measurement")
        .parse(std::env::args().skip(1))
        .unwrap();
    let mut e = if a.get_bool("quick") {
        Effort::quick()
    } else {
        Effort::default()
    };
    if let Some(n) = a.get("leech-blocks").and_then(|v| v.parse().ok()) {
        e.leech_blocks = n;
    }
    fig1(&e);
    table4(&e);
}
