"""Leech lattice combinatorics — build-path twin of `rust/src/leech/`.

Independent re-implementation (numpy-free pure python for the exact
integer parts) of the Golay code, shell/class/subclass enumeration, and
the flattened kernel dequantization tables. Orderings are canonical and
MUST match the rust side bit-for-bit:

* classes within a shell: even before odd, then ascending value tuple;
* subclasses within an odd class: by (weight, split) ascending;
* Golay codewords: ascending within each weight bucket; buckets in weight
  order 0, 8, 12, 16, 24.

Validated against the theta series n(m) = 65520/691·(σ₁₁(m) − τ(m)) in
pytest; cross-checked against the rust JSON export when present.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from math import factorial

DIM = 24
WEIGHTS = (0, 8, 12, 16, 24)
MAX_DISTINCT = 8

# --------------------------------------------------------------------------
# Golay code — same QR-mod-11 bordered-circulant generator as golay.rs
# --------------------------------------------------------------------------

_B_STR = [
    "111111111110",
    "110111000101",
    "011011100011",
    "101101110001",
    "010110111001",
    "001011011101",
    "000101101111",
    "100010110111",
    "110001011011",
    "111000101101",
    "011100010111",
    "101110001011",
]


@lru_cache(maxsize=1)
def golay_codewords() -> list[int]:
    """All 4096 codewords as 24-bit ints, ascending."""
    rows = []
    for i, s in enumerate(_B_STR):
        w = 1 << i
        for j, c in enumerate(s):
            if c == "1":
                w |= 1 << (12 + j)
        rows.append(w)
    out = []
    for m in range(4096):
        c = 0
        mm, i = m, 0
        while mm:
            if mm & 1:
                c ^= rows[i]
            mm >>= 1
            i += 1
        out.append(c)
    out.sort()
    wd: dict[int, int] = {}
    for c in out:
        wd[bin(c).count("1")] = wd.get(bin(c).count("1"), 0) + 1
    assert wd == {0: 1, 8: 759, 12: 2576, 16: 759, 24: 1}, f"bad generator: {wd}"
    return out


@lru_cache(maxsize=1)
def golay_by_weight() -> dict[int, list[int]]:
    buckets: dict[int, list[int]] = {w: [] for w in WEIGHTS}
    for c in golay_codewords():
        buckets[bin(c).count("1")].append(c)
    return buckets


# --------------------------------------------------------------------------
# Theta series ground truth
# --------------------------------------------------------------------------

def theta_shell_sizes(max_m: int) -> list[int]:
    """n(m) for m = 0..max_m (n(0)=1, n(1)=0)."""
    n = max_m
    coef = [0] * max(n, 1)
    coef[0] = 1
    for k in range(1, n):
        for _ in range(24):
            for i in range(n - 1, k - 1, -1):
                coef[i] -= coef[i - k]
    out = [1]
    for m in range(1, max_m + 1):
        tau = coef[m - 1] if m - 1 < len(coef) else 0
        sigma11 = sum(d ** 11 for d in range(1, m + 1) if m % d == 0)
        v = 65520 * (sigma11 - tau)
        assert v % 691 == 0
        out.append(v // 691)
    return out


# --------------------------------------------------------------------------
# Shell → class → subclass enumeration (mirrors leaders.rs)
# --------------------------------------------------------------------------

@dataclass
class Subclass:
    weight: int
    num_codewords: int
    split: tuple[int, ...]
    f1_seq: tuple[int, ...]
    f0_seq: tuple[int, ...]
    f1_arrangements: int
    f0_arrangements: int
    sign_bits: int
    size: int


@dataclass
class ClassInfo:
    parity: str  # "even" | "odd"
    values: tuple[int, ...]
    counts: tuple[tuple[int, int], ...]
    f1_neg_parity: int
    subclasses: list[Subclass] = field(default_factory=list)
    size: int = 0


def _enumerate_value_multisets(total: int, parity: int):
    out: list[tuple[int, ...]] = []
    seq: list[int] = []

    def rec(remaining: int, slots: int, cap: int):
        if slots == 0:
            if remaining == 0:
                out.append(tuple(seq))
            return
        minv = 0 if parity == 0 else 1
        if remaining < minv * minv * slots:
            return
        v = cap
        while v >= minv:
            vv = v * v
            if vv <= remaining and remaining - vv <= (slots - 1) * vv:
                seq.append(v)
                rec(remaining - vv, slots - 1, v)
                seq.pop()
            v -= 2
            if parity == 1 and v < 1:
                break
        return

    cap = int(total ** 0.5)
    while cap > 0 and (cap % 2 != parity or cap * cap > total):
        cap -= 1
    if cap >= parity:
        rec(total, DIM, cap)
    return out


def _counts(values) -> tuple[tuple[int, int], ...]:
    out: list[list[int]] = []
    for v in values:
        if out and out[-1][0] == v:
            out[-1][1] += 1
        else:
            out.append([v, 1])
    return tuple((a, b) for a, b in out)


def _ms_arrangements(length: int, mults) -> int:
    v = factorial(length)
    for m in mults:
        v //= factorial(m)
    return v


def odd_signed_value(abs_v: int, in_f1: bool) -> int:
    if in_f1:
        return abs_v if abs_v % 4 == 3 else -abs_v
    return abs_v if abs_v % 4 == 1 else -abs_v


def _build_even_class(values) -> ClassInfo | None:
    counts = _counts(values)
    w = sum(1 for v in values if v % 4 == 2)
    if w not in WEIGHTS:
        return None
    a = len(golay_by_weight()[w])
    total = sum(values)
    if w == 0 and total % 8 != 0:
        return None
    f1_neg_parity = (total % 8) // 4
    f1_seq = tuple(v for v in values if v % 4 == 2)
    f0_seq = tuple(v for v in values if v % 4 == 0)
    split = tuple(c if v % 4 == 2 else 0 for v, c in counts)
    f1_arr = _ms_arrangements(w, [c for v, c in counts if v % 4 == 2])
    f0_arr = _ms_arrangements(DIM - w, [c for v, c in counts if v % 4 == 0])
    n_f0_nonzero = sum(1 for v in f0_seq if v != 0)
    sign_bits = n_f0_nonzero + (w - 1 if w > 0 else 0)
    size = a * (1 << sign_bits) * f1_arr * f0_arr
    sub = Subclass(w, a, split, f1_seq, f0_seq, f1_arr, f0_arr, sign_bits, size)
    return ClassInfo("even", values, counts, f1_neg_parity, [sub], size)


def _build_odd_class(values) -> ClassInfo | None:
    counts = _counts(values)
    subclasses: list[Subclass] = []
    for w in WEIGHTS:
        a = len(golay_by_weight()[w])
        items = list(counts)

        def rec(i: int, left: int, summ: int, split: list[int]):
            if i == len(items):
                if left == 0 and summ % 8 == 4:
                    f1_seq, f0_seq, f1m, f0m = [], [], [], []
                    for (v, c), k in zip(items, split):
                        f1_seq += [v] * k
                        f0_seq += [v] * (c - k)
                        if k:
                            f1m.append(k)
                        if c - k:
                            f0m.append(c - k)
                    f1_arr = _ms_arrangements(w, f1m)
                    f0_arr = _ms_arrangements(DIM - w, f0m)
                    subclasses.append(
                        Subclass(
                            w, a, tuple(split), tuple(f1_seq), tuple(f0_seq),
                            f1_arr, f0_arr, 0, a * f1_arr * f0_arr,
                        )
                    )
                return
            v, c = items[i]
            cap_rest = sum(cc for _, cc in items[i + 1:])
            for k in range(0, min(c, left) + 1):
                if left - k > cap_rest:
                    continue
                rec(
                    i + 1,
                    left - k,
                    summ + k * odd_signed_value(v, True) + (c - k) * odd_signed_value(v, False),
                    split + [k],
                )

        rec(0, w, 0, [])
    if not subclasses:
        return None
    subclasses.sort(key=lambda s: (s.weight, s.split))
    size = sum(s.size for s in subclasses)
    return ClassInfo("odd", values, counts, 0, subclasses, size)


@lru_cache(maxsize=32)
def enumerate_shell(m: int) -> list[ClassInfo]:
    total = 16 * m
    classes: list[ClassInfo] = []
    for values in _enumerate_value_multisets(total, 0):
        c = _build_even_class(values)
        if c:
            classes.append(c)
    for values in _enumerate_value_multisets(total, 1):
        c = _build_odd_class(values)
        if c:
            classes.append(c)
    classes.sort(key=lambda c: (0 if c.parity == "even" else 1, c.values))
    return classes


# --------------------------------------------------------------------------
# Flattened kernel tables (mirrors tables.rs)
# --------------------------------------------------------------------------

@dataclass
class KernelTables:
    max_m: int
    num_groups: int
    group_offsets: list[int]
    weight: list[int]
    num_codewords: list[int]
    cw_base: list[int]
    sign_bits: list[int]
    parity_odd: list[int]
    f1_neg_parity: list[int]
    f0_arrangements: list[int]
    f1_arrangements: list[int]
    f1_values: list[int]
    f1_counts: list[int]
    f0_values: list[int]
    f0_counts: list[int]
    golay_sorted: list[int]
    weight_offsets: list[int]

    def num_points(self) -> int:
        return self.group_offsets[-1]

    def index_bits(self) -> int:
        return (self.num_points() - 1).bit_length()


def build_tables(max_m: int) -> KernelTables:
    golay_sorted: list[int] = []
    weight_offsets = [0]
    for w in WEIGHTS:
        golay_sorted += golay_by_weight()[w]
        weight_offsets.append(len(golay_sorted))
    cw_base = {w: weight_offsets[i] for i, w in enumerate(WEIGHTS)}

    t = KernelTables(
        max_m=max_m, num_groups=0, group_offsets=[0], weight=[], num_codewords=[],
        cw_base=[], sign_bits=[], parity_odd=[], f1_neg_parity=[],
        f0_arrangements=[], f1_arrangements=[], f1_values=[], f1_counts=[],
        f0_values=[], f0_counts=[], golay_sorted=golay_sorted,
        weight_offsets=weight_offsets,
    )
    acc = 0
    for m in range(2, max_m + 1):
        for cls in enumerate_shell(m):
            for sub in cls.subclasses:
                acc += sub.size
                t.group_offsets.append(acc)
                t.weight.append(sub.weight)
                t.num_codewords.append(sub.num_codewords)
                t.cw_base.append(cw_base[sub.weight])
                t.sign_bits.append(sub.sign_bits)
                t.parity_odd.append(1 if cls.parity == "odd" else 0)
                t.f1_neg_parity.append(cls.f1_neg_parity)
                t.f0_arrangements.append(sub.f0_arrangements)
                t.f1_arrangements.append(sub.f1_arrangements)
                for seq, vals, cnts in (
                    (sub.f1_seq, t.f1_values, t.f1_counts),
                    (sub.f0_seq, t.f0_values, t.f0_counts),
                ):
                    pairs = _counts(seq) if seq else ()
                    assert len(pairs) <= MAX_DISTINCT
                    for k in range(MAX_DISTINCT):
                        if k < len(pairs):
                            vals.append(pairs[k][0])
                            cnts.append(pairs[k][1])
                        else:
                            vals.append(0)
                            cnts.append(0)
    t.num_groups = len(t.weight)
    return t


# --------------------------------------------------------------------------
# Membership check (for test oracles)
# --------------------------------------------------------------------------

def is_lattice_point(x) -> bool:
    cws = set(golay_codewords())
    parities = {v % 2 for v in x}
    if len(parities) != 1:
        return False
    if parities == {0}:
        word = 0
        for i, v in enumerate(x):
            if (v // 2) % 2 == 1:
                word |= 1 << i
        return word in cws and sum(x) % 8 == 0
    word = 0
    for i, v in enumerate(x):
        if v % 4 == 3:
            word |= 1 << i
    return word in cws and sum(x) % 8 == 4
