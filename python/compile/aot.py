"""AOT compile path: lower the L2/L1 JAX graphs to HLO **text** artifacts
for the rust PJRT runtime.

Artifacts (under artifacts/):
* `dequant_M<maxm>_N<batch>.hlo.txt` — the L1 Pallas dequantization kernel
  wrapped for a fixed index-batch (tables are runtime INPUTS, so the HLO
  stays table-agnostic; rust regenerates tables natively and feeds them).
* `lm_forward_<model>_B<batch>.hlo.txt` — dense LM forward (weights as
  inputs, canonical .llvqw order).
* `quant_linear_M<maxm>.hlo.txt` — quantized-linear: indices + gains +
  tables + activations → output (the kernel on the inference path).
* `model.hlo.txt` — alias of the llama2-tiny B=1 forward (Makefile stamp).
* `config.json` — all static shapes the rust side needs.

HLO text, NOT `.serialize()`: jax ≥ 0.5 emits 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import leech  # noqa: E402
from compile import model as M  # noqa: E402
from compile.kernels import llvq_dequant as kd  # noqa: E402

MAX_M = 13
DEQUANT_BATCH = 768
QL_ROWS, QL_COLS = 144, 144  # llama2-tiny attention projection shape


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def table_specs(tb) -> list:
    return [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in tb.values()]


def lower_dequant(tb, n: int) -> str:
    keys = list(tb.keys())

    def fn(idx, *tabs):
        d = dict(zip(keys, tabs))
        return (kd.pallas_dequant(idx, d, tile=256),)

    idx_spec = jax.ShapeDtypeStruct((n,), jnp.int64)
    lowered = jax.jit(fn).lower(idx_spec, *table_specs(tb))
    return to_hlo_text(lowered)


def lower_quant_linear(tb, rows: int, cols: int, batch: int) -> str:
    keys = list(tb.keys())
    nblocks = rows * cols // 24

    def fn(idx, gains, x, *tabs):
        d = dict(zip(keys, tabs))
        return (M.quantized_linear(idx, gains, d, x, rows, cols),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((nblocks,), jnp.int64),
        jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        jax.ShapeDtypeStruct((batch, cols), jnp.float32),
        *table_specs(tb),
    )
    return to_hlo_text(lowered)


def lower_lm_forward(cfg: dict, batch: int) -> str:
    def fn(tokens, *flat):
        params = M.flat_to_params(list(flat), cfg)
        return (M.forward(params, tokens, cfg),)

    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in M.flat_shapes(cfg)]
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, cfg["max_seq"]), jnp.int32), *specs
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="(legacy) single-output path stamp")
    ap.add_argument("--outdir", default=None)
    ap.add_argument("--train-steps", type=int, default=260)
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()

    root = Path(__file__).resolve().parent.parent.parent
    outdir = Path(args.outdir) if args.outdir else root / "artifacts"
    outdir.mkdir(parents=True, exist_ok=True)

    # 1. train the model zoo (skipped when weights already exist)
    if not args.skip_train:
        from compile.train import train_zoo

        train_zoo(outdir, steps=args.train_steps)

    # 2. lattice tables (shape metadata for config.json + kernel lowering)
    print(f"building Leech tables (M={MAX_M}) …", flush=True)
    t = leech.build_tables(MAX_M)
    tb = kd.tables_to_arrays(t)

    # 3. lower the kernels
    print("lowering dequant kernel …", flush=True)
    (outdir / f"dequant_M{MAX_M}_N{DEQUANT_BATCH}.hlo.txt").write_text(
        lower_dequant(tb, DEQUANT_BATCH)
    )
    print("lowering quantized linear …", flush=True)
    (outdir / f"quant_linear_M{MAX_M}.hlo.txt").write_text(
        lower_quant_linear(tb, QL_ROWS, QL_COLS, batch=8)
    )

    # 4. lower LM forwards
    for cfg in M.config_zoo():
        for batch in (1, 8):
            print(f"lowering lm_forward {cfg['name']} B={batch} …", flush=True)
            (outdir / f"lm_forward_{cfg['name']}_B{batch}.hlo.txt").write_text(
                lower_lm_forward(cfg, batch)
            )

    # Makefile stamp artifact
    stamp = outdir / "model.hlo.txt"
    stamp.write_text((outdir / "lm_forward_llama2-tiny_B1.hlo.txt").read_text())

    # 5. config.json — static shapes for the rust runtime
    config = {
        "max_m": MAX_M,
        "dequant_batch": DEQUANT_BATCH,
        "num_groups": t.num_groups,
        "num_points": str(t.num_points()),  # exceeds 2^53: keep as string
        "index_bits": t.index_bits(),
        "table_keys": list(tb.keys()),
        "table_shapes": {k: list(v.shape) for k, v in tb.items()},
        "table_dtypes": {k: str(v.dtype) for k, v in tb.items()},
        "quant_linear": {"rows": QL_ROWS, "cols": QL_COLS, "batch": 8},
        "models": [c["name"] for c in M.config_zoo()],
        "lm_batches": [1, 8],
    }
    (outdir / "config.json").write_text(json.dumps(config, indent=2))
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(stamp.read_text())
    print(f"artifacts complete in {outdir}")


if __name__ == "__main__":
    main()
