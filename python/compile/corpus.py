"""Synthetic structured corpus — exact python twin of `rust/src/model/corpus.rs`.

Token-for-token identical streams for a given seed (pinned by a golden
prefix test in both suites), so the JAX-trained models and the rust
evaluation pipeline see the same distribution. Includes a faithful port
of the crate's xoshiro256++ / SplitMix64 generators.
"""

from __future__ import annotations

from bisect import bisect_left

VOCAB = 64
NUM_MOTIFS = 8
MOTIF_LEN = 6
_M64 = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed: int):
        self.state = seed & _M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
        return z ^ (z >> 31)


def _rotl(x: int, k: int) -> int:
    return ((x << k) | (x >> (64 - k))) & _M64


class Xoshiro256pp:
    def __init__(self, seed: int):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self) -> int:
        s = self.s
        result = (_rotl((s[0] + s[3]) & _M64, 23) + s[0]) & _M64
        t = (s[1] << 17) & _M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_range(self, n: int) -> int:
        """Lemire's unbiased bounded generation (matches rng.rs)."""
        x = self.next_u64()
        m = x * n
        lo = m & _M64
        if lo < n:
            t = ((1 << 64) - n) % n
            while lo < t:
                x = self.next_u64()
                m = x * n
                lo = m & _M64
        return m >> 64


class Corpus:
    """Second-order-ish Markov backbone + deterministic motifs."""

    def __init__(self, seed: int):
        setup = Xoshiro256pp(0xC0FFEE)  # fixed language; seed only drives sampling
        self.trans: list[list[int]] = []
        for _ in range(VOCAB):
            w = [1.0 + setup.next_range(97) for _ in range(VOCAB)]
            for _ in range(6):
                w[setup.next_range(VOCAB)] *= 24.0
            total = sum(w)
            row, acc = [], 0.0
            for c in range(VOCAB):
                acc += w[c]
                row.append(int(acc / total * 65535.0))
            row[VOCAB - 1] = 65535
            self.trans.append(row)
        self.motifs = [
            [setup.next_range(VOCAB) for _ in range(MOTIF_LEN)] for _ in range(NUM_MOTIFS)
        ]
        self.rng = Xoshiro256pp(seed)
        self.motif_p16 = int(0.08 * 65536.0)

    def generate(self, n: int):
        out: list[int] = []
        det: list[bool] = []
        prev = 0
        while len(out) < n:
            if (self.rng.next_u64() & 0xFFFF) < self.motif_p16:
                m = self.motifs[self.rng.next_range(NUM_MOTIFS)]
                for k, t in enumerate(m):
                    if len(out) >= n:
                        break
                    out.append(t)
                    det.append(k >= 2)
                    prev = t
            else:
                u = self.rng.next_u64() & 0xFFFF
                row = self.trans[prev]
                c = min(bisect_left(row, u), VOCAB - 1)
                out.append(c)
                det.append(False)
                prev = c
        return out, det

    def sequences(self, count: int, seq_len: int):
        return [self.generate(seq_len + 1) for _ in range(count)]
