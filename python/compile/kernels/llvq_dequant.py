"""L1 — the fully parallel LLVQ dequantization kernel (paper §3.3 step 5).

The paper proposes a CUDA kernel; the TPU/Pallas adaptation (DESIGN.md
§Hardware-Adaptation) is a *table-driven, branch-free* block program:

* shell/class/subclass lookup = one `searchsorted` over the cumulative
  group-offset table (VMEM-resident, ~1.8 MiB at M=13);
* the local-symmetry unflattening (paper eq. 15) = fixed-radix integer
  div/mod;
* the two multiset-permutation unranks = a fixed 24-step loop over ≤ 8
  symbol slots — no data-dependent trip counts, fully vectorizable across
  the index batch (lane dimension);
* sign assembly = popcount-style prefix sums + bit tests.

`dequant_batch` is the pure-jnp computation; `pallas_dequant` wraps it in
a `pallas_call` with a batch-tiled BlockSpec (tables replicated per tile).
interpret=True everywhere — the CPU image cannot execute Mosaic custom
calls; real-TPU performance is *estimated* in EXPERIMENTS.md.

All integer arithmetic is int64 (indices reach 2^48); x64 must be enabled
before importing jax.numpy (compile.aot does this).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DIM = 24
VSLOTS = 8  # MAX_DISTINCT


def tables_to_arrays(t) -> dict[str, jnp.ndarray]:
    """KernelTables (compile.leech) → jnp arrays keyed for dequant_batch."""
    g = t.num_groups
    return {
        "group_offsets": jnp.asarray(t.group_offsets, jnp.int64),
        "num_codewords": jnp.asarray(t.num_codewords, jnp.int64),
        "sign_bits": jnp.asarray(t.sign_bits, jnp.int64),
        "f0_arrangements": jnp.asarray(t.f0_arrangements, jnp.int64),
        "f1_arrangements": jnp.asarray(t.f1_arrangements, jnp.int64),
        "weight": jnp.asarray(t.weight, jnp.int32),
        "cw_base": jnp.asarray(t.cw_base, jnp.int32),
        "parity_odd": jnp.asarray(t.parity_odd, jnp.int32),
        "f1_neg_parity": jnp.asarray(t.f1_neg_parity, jnp.int32),
        "f1_values": jnp.asarray(t.f1_values, jnp.int32).reshape(g, VSLOTS),
        "f1_counts": jnp.asarray(t.f1_counts, jnp.int32).reshape(g, VSLOTS),
        "f0_values": jnp.asarray(t.f0_values, jnp.int32).reshape(g, VSLOTS),
        "f0_counts": jnp.asarray(t.f0_counts, jnp.int32).reshape(g, VSLOTS),
        "golay_sorted": jnp.asarray(t.golay_sorted, jnp.int32),
    }


def _unrank_sequence(rank, total0, counts, values, active_len):
    """Vectorized multiset-permutation unrank.

    rank, total0: [N] int64 ; counts, values: [N, VSLOTS] ; active_len: [N].
    Returns seq [N, DIM] (positions ≥ active_len are padding zeros).
    """
    n = rank.shape[0]
    cnt = counts.astype(jnp.int64)
    total = total0
    rem = active_len.astype(jnp.int64)
    seq = jnp.zeros((n, DIM), jnp.int32)
    for pos in range(DIM):
        active = pos < active_len  # [N] bool
        rem_safe = jnp.maximum(rem, 1)
        # per-symbol arrangement counts when that symbol is placed first
        c = total[:, None] * cnt // rem_safe[:, None]  # [N, V]
        cum = jnp.cumsum(c, axis=1)
        cum_prev = cum - c
        picked = (rank[:, None] >= cum_prev) & (rank[:, None] < cum) & (cnt > 0)
        picked &= active[:, None]
        kstar = jnp.argmax(picked, axis=1)  # first True (picked is exclusive)
        any_pick = picked.any(axis=1)
        sel = jax.nn.one_hot(kstar, VSLOTS, dtype=jnp.int64) * any_pick[:, None]
        val = jnp.sum(values.astype(jnp.int32) * sel.astype(jnp.int32), axis=1)
        new_rank = rank - jnp.sum(cum_prev * sel, axis=1)
        new_total = jnp.sum(c * sel, axis=1)
        seq = seq.at[:, pos].set(jnp.where(active, val, 0))
        rank = jnp.where(active, new_rank, rank)
        total = jnp.where(active, new_total, total)
        cnt = cnt - sel * active[:, None]
        rem = jnp.where(active, rem - 1, rem)
    return seq


def dequant_batch(idx, tb) -> jnp.ndarray:
    """Batched dequantization: idx [N] int64 → integer points [N, 24] int32.

    Mirrors `leech::tables::KernelTables::dequantize` exactly.
    """
    idx = idx.astype(jnp.int64)
    g = jnp.searchsorted(tb["group_offsets"], idx, side="right") - 1
    local = idx - tb["group_offsets"][g]

    a = tb["num_codewords"][g]
    c_rank = local % a
    local = local // a
    b = tb["sign_bits"][g]
    sign_rank = local & ((jnp.int64(1) << b) - 1)
    local = local >> b
    f0a = tb["f0_arrangements"][g]
    f1_rank = local // f0a
    f0_rank = local % f0a

    codeword = tb["golay_sorted"][tb["cw_base"][g] + c_rank.astype(jnp.int32)]
    w = tb["weight"][g].astype(jnp.int64)  # [N]

    f1_seq = _unrank_sequence(
        f1_rank, tb["f1_arrangements"][g], tb["f1_counts"][g], tb["f1_values"][g], w
    )
    f0_seq = _unrank_sequence(
        f0_rank, f0a, tb["f0_counts"][g], tb["f0_values"][g], jnp.int64(DIM) - w
    )

    pos = jnp.arange(DIM, dtype=jnp.int32)
    bits = (codeword[:, None] >> pos[None, :]) & 1  # [N, 24] int32
    # prefix position of each coordinate within F1 / F0
    incl = jnp.cumsum(bits, axis=1)
    pos_f1 = incl - bits  # exclusive prefix count of set bits
    pos_f0 = pos[None, :] - pos_f1
    v_f1 = jnp.take_along_axis(f1_seq, pos_f1, axis=1)
    v_f0 = jnp.take_along_axis(f0_seq, jnp.minimum(pos_f0, DIM - 1), axis=1)
    val = jnp.where(bits == 1, v_f1, v_f0)  # [N, 24] abs values

    # --- odd coset: congruence-forced signs ---
    odd_f1 = jnp.where(val % 4 == 3, val, -val)
    odd_f0 = jnp.where(val % 4 == 1, val, -val)
    x_odd = jnp.where(bits == 1, odd_f1, odd_f0)

    # --- even coset: F0 free signs, F1 parity-constrained ---
    mask_f0nz = (bits == 0) & (val != 0)
    bitidx_f0 = jnp.cumsum(mask_f0nz.astype(jnp.int64), axis=1) - mask_f0nz
    n_f0nz = jnp.sum(mask_f0nz, axis=1).astype(jnp.int64)  # [N]
    last_f1 = jnp.max(pos[None, :] * bits, axis=1)  # [N] (0 when w = 0)
    mask_f1_free = (bits == 1) & (pos[None, :] != last_f1[:, None])
    bitidx_f1 = (
        n_f0nz[:, None]
        + jnp.cumsum(mask_f1_free.astype(jnp.int64), axis=1)
        - mask_f1_free
    )
    bitidx = jnp.where(mask_f0nz, bitidx_f0, bitidx_f1)
    neg = ((sign_rank[:, None] >> bitidx) & 1) == 1
    sign_mask = mask_f0nz | mask_f1_free
    x_even = jnp.where(sign_mask & neg, -val, val)
    # parity repair on the last F1 coordinate
    negs_f1 = jnp.sum((mask_f1_free & neg).astype(jnp.int32), axis=1)
    need_flip = (negs_f1 % 2 != tb["f1_neg_parity"][g]) & (w.astype(jnp.int32) > 0)
    at_last = pos[None, :] == last_f1[:, None]
    x_even = jnp.where(at_last & need_flip[:, None] & (bits == 1), -x_even, x_even)

    is_odd = (tb["parity_odd"][g] == 1)[:, None]
    return jnp.where(is_odd, x_odd, x_even).astype(jnp.int32)


def dequant_f32(idx, tb, scale) -> jnp.ndarray:
    """Real-coordinate reconstruction: points/√8 × scale → [N, 24] f32."""
    x = dequant_batch(idx, tb).astype(jnp.float32)
    return x * (scale / jnp.sqrt(jnp.float32(8.0)))


# --------------------------------------------------------------------------
# Pallas wrapper
# --------------------------------------------------------------------------

_TABLE_KEYS = [
    "group_offsets", "num_codewords", "sign_bits", "f0_arrangements",
    "f1_arrangements", "weight", "cw_base", "parity_odd", "f1_neg_parity",
    "f1_values", "f1_counts", "f0_values", "f0_counts", "golay_sorted",
]


def pallas_dequant(idx, tb, tile: int = 256) -> jnp.ndarray:
    """Pallas-tiled batched dequantization (interpret mode on CPU).

    The index stream is tiled HBM→VMEM; the lattice tables ride along
    replicated per tile (BlockSpec index_map pinning block 0) — the TPU
    analogue of the paper's "small static tables in shared memory".
    """
    n = idx.shape[0]
    assert n % tile == 0, f"batch {n} not a multiple of tile {tile}"
    tabs = [tb[k] for k in _TABLE_KEYS]

    def kernel(idx_ref, *refs):
        table_refs, o_ref = refs[:-1], refs[-1]
        tbl = {k: r[...] for k, r in zip(_TABLE_KEYS, table_refs)}
        o_ref[...] = dequant_batch(idx_ref[...], tbl)

    whole = lambda t: pl.BlockSpec(t.shape, lambda i: tuple(0 for _ in t.shape))
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))] + [whole(t) for t in tabs],
        out_specs=pl.BlockSpec((tile, DIM), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, DIM), jnp.int32),
        interpret=True,
    )(idx, *tabs)
