"""Pure-python reference dequantizer — the correctness oracle for the
Pallas kernel (paper §3.3 steps 1–4, scalar form).

Deliberately written as a straightforward per-index implementation with
exact integer arithmetic (no numpy), structurally independent of both the
vectorized kernel and the rust implementation, so agreement between the
three is strong evidence of correctness.
"""

from __future__ import annotations

from compile.leech import DIM, MAX_DISTINCT, KernelTables, odd_signed_value


def _unrank_multiset(values, counts, length: int, rank: int) -> list[int]:
    from math import factorial

    cnt = list(counts)
    total = factorial(length)
    for c in cnt:
        total //= factorial(c)
    rem = length
    out = []
    for _ in range(length):
        for k in range(MAX_DISTINCT):
            if cnt[k] == 0:
                continue
            c = total * cnt[k] // rem
            if rank < c:
                out.append(values[k])
                total = c
                cnt[k] -= 1
                rem -= 1
                break
            rank -= c
        else:
            raise AssertionError("unrank ran out of symbols")
    assert rank == 0 or length == 0
    return out


def dequantize_ref(t: KernelTables, index: int) -> list[int]:
    """Global index → integer lattice point (L^int coordinates)."""
    assert 0 <= index < t.num_points()
    # 1. group (shell/class/subclass) identification
    lo, hi = 0, len(t.group_offsets) - 1
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if t.group_offsets[mid] <= index:
            lo = mid
        else:
            hi = mid
    g = lo
    local = index - t.group_offsets[g]

    # 2. unpack local symmetries (paper eq. 15)
    a = t.num_codewords[g]
    c_rank = local % a
    local //= a
    b = t.sign_bits[g]
    sign_rank = local & ((1 << b) - 1)
    local >>= b
    f0_arr = t.f0_arrangements[g]
    f1_rank, f0_rank = local // f0_arr, local % f0_arr

    codeword = t.golay_sorted[t.cw_base[g] + c_rank]
    w = t.weight[g]

    # 3. multiset-permutation unranks
    row = slice(g * MAX_DISTINCT, (g + 1) * MAX_DISTINCT)
    f1_vals = _unrank_multiset(t.f1_values[row], t.f1_counts[row], w, f1_rank)
    f0_vals = _unrank_multiset(t.f0_values[row], t.f0_counts[row], DIM - w, f0_rank)

    # 4. assemble with signs
    x = [0] * DIM
    if t.parity_odd[g]:
        i1 = i0 = 0
        for i in range(DIM):
            if codeword >> i & 1:
                x[i] = odd_signed_value(f1_vals[i1], True)
                i1 += 1
            else:
                x[i] = odd_signed_value(f0_vals[i0], False)
                i0 += 1
        return x

    bit = 0
    i1 = i0 = 0
    f1_pos = [i for i in range(DIM) if codeword >> i & 1]
    for i in range(DIM):
        if codeword >> i & 1:
            x[i] = f1_vals[i1]
            i1 += 1
        else:
            v = f0_vals[i0]
            i0 += 1
            if v != 0:
                x[i] = -v if (sign_rank >> bit) & 1 else v
                bit += 1
    if w > 0:
        negs = 0
        for i in f1_pos[:-1]:
            if (sign_rank >> bit) & 1:
                x[i] = -x[i]
                negs += 1
            bit += 1
        if negs % 2 != t.f1_neg_parity[g]:
            x[f1_pos[-1]] = -x[f1_pos[-1]]
    assert bit == b
    return x


def dequantize_ref_f32(t: KernelTables, index: int, scale: float = 1.0) -> list[float]:
    """Real-coordinate reconstruction: x/√8 × scale."""
    sqrt8 = 8.0 ** 0.5
    return [v / sqrt8 * scale for v in dequantize_ref(t, index)]
