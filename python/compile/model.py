"""L2 — the JAX model: tiny decoder-only transformer, exact twin of
`rust/src/model/transformer.rs`.

Used in two roles:
* build-time training (`compile.train`) of the model zoo;
* the AOT-lowered forward graph (`compile.aot`) the rust runtime executes
  through PJRT, including the *quantized-linear* variant that routes its
  weights through the L1 dequantization kernel so the paper's kernel sits
  on the compiled inference path.

Weight pytree layout mirrors the `.llvqw` serialization order.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

VOCAB = 64


def config_zoo():
    """Mirror of `model::config::model_zoo()`."""
    mk = lambda name, d, l, h, f: dict(
        name=name, vocab=VOCAB, d_model=d, n_layers=l, n_heads=h, d_ff=f, max_seq=64
    )
    return [
        mk("llama2-tiny", 144, 3, 6, 384),
        mk("llama3-tiny", 168, 3, 7, 456),
        mk("ministral-tiny", 144, 4, 6, 384),
        mk("qwen3-4b-tiny", 120, 2, 5, 308),
        mk("qwen3-8b-tiny", 168, 4, 7, 432),
    ]


def config_by_name(name: str) -> dict:
    for c in config_zoo():
        if c["name"] == name:
            return c
    raise KeyError(name)


def init_params(cfg: dict, key) -> dict:
    d, f, v, s = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["max_seq"]
    ks = jax.random.split(key, 4 + 8 * cfg["n_layers"])
    ki = iter(ks)
    s_attn = 1.0 / math.sqrt(d)
    s_mlp = 1.0 / math.sqrt(f)
    blocks = []
    for _ in range(cfg["n_layers"]):
        blocks.append(
            dict(
                norm1=jnp.ones((d,), jnp.float32),
                wq=jax.random.normal(next(ki), (d, d), jnp.float32) * s_attn,
                wk=jax.random.normal(next(ki), (d, d), jnp.float32) * s_attn,
                wv=jax.random.normal(next(ki), (d, d), jnp.float32) * s_attn,
                wo=jax.random.normal(next(ki), (d, d), jnp.float32) * s_attn,
                norm2=jnp.ones((d,), jnp.float32),
                w1=jax.random.normal(next(ki), (f, d), jnp.float32) * s_attn,
                w2=jax.random.normal(next(ki), (d, f), jnp.float32) * s_mlp,
            )
        )
    return dict(
        tok_emb=jax.random.normal(next(ki), (v, d), jnp.float32) * 0.05,
        pos_emb=jax.random.normal(next(ki), (s, d), jnp.float32) * 0.05,
        blocks=blocks,
        norm_f=jnp.ones((d,), jnp.float32),
        lm_head=jax.random.normal(next(ki), (v, d), jnp.float32) * s_attn,
    )


def _rmsnorm(x, gamma):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + 1e-6) * gamma


def forward(params: dict, tokens, cfg: dict):
    """tokens [B, S] int32 → logits [B, S, vocab]. Causal MHA, head dim 24,
    SiLU MLP — numerics match the rust oracle to f32 tolerance."""
    b, s = tokens.shape
    d = cfg["d_model"]
    nh = cfg["n_heads"]
    hd = d // nh
    h = params["tok_emb"][tokens] + params["pos_emb"][None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    for blk in params["blocks"]:
        x = _rmsnorm(h, blk["norm1"])
        q = (x @ blk["wq"].T).reshape(b, s, nh, hd)
        k = (x @ blk["wk"].T).reshape(b, s, nh, hd)
        v = (x @ blk["wv"].T).reshape(b, s, nh, hd)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        scores = jnp.where(mask[None, None, :, :], scores, -jnp.inf)
        p = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
        h = h + attn @ blk["wo"].T
        x = _rmsnorm(h, blk["norm2"])
        ff = jax.nn.silu(x @ blk["w1"].T)
        h = h + ff @ blk["w2"].T
    h = _rmsnorm(h, params["norm_f"])
    return h @ params["lm_head"].T


def loss_fn(params: dict, tokens, targets, cfg: dict):
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# Quantized-linear forward — the L1 kernel on the compiled inference path
# --------------------------------------------------------------------------

def quantized_linear(idx, gains, tb, x, rows: int, cols: int, use_pallas: bool = True):
    """y = Ŵ·x where Ŵ is reconstructed from LLVQ shape–gain codes.

    idx   [nblocks] int64 — lattice indices (nblocks = rows·cols/24),
    gains [nblocks] f32   — per-block gains (shape–gain) already divided
                            by the lattice point norm, i.e. Ŵ_block =
                            point · gains,
    x     [cols] or [B, cols] f32.
    """
    from compile.kernels import llvq_dequant as kd

    n = idx.shape[0]
    assert n * 24 == rows * cols, "block count must tile the matrix exactly"
    pts = (
        kd.pallas_dequant(idx, tb, tile=_tile_for(n))
        if use_pallas
        else kd.dequant_batch(idx, tb)
    ).astype(jnp.float32)
    w_hat = (pts * gains[:, None]).reshape(rows, cols)
    return x @ w_hat.T


def _tile_for(n: int) -> int:
    for t in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % t == 0:
            return t
    return 1


# --------------------------------------------------------------------------
# Flat weight I/O (the .llvqw canonical order) for AOT argument passing
# --------------------------------------------------------------------------

def params_to_flat(params: dict) -> list:
    flat = [params["tok_emb"], params["pos_emb"]]
    for blk in params["blocks"]:
        flat += [blk[k] for k in ("norm1", "wq", "wk", "wv", "wo", "norm2", "w1", "w2")]
    flat += [params["norm_f"], params["lm_head"]]
    return flat


def flat_to_params(flat: list, cfg: dict) -> dict:
    it = iter(flat)
    params = dict(tok_emb=next(it), pos_emb=next(it), blocks=[])
    for _ in range(cfg["n_layers"]):
        params["blocks"].append(
            dict(
                norm1=next(it), wq=next(it), wk=next(it), wv=next(it),
                wo=next(it), norm2=next(it), w1=next(it), w2=next(it),
            )
        )
    params["norm_f"] = next(it)
    params["lm_head"] = next(it)
    return params


def flat_shapes(cfg: dict) -> list[tuple[int, ...]]:
    d, f, v, s = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["max_seq"]
    shapes = [(v, d), (s, d)]
    for _ in range(cfg["n_layers"]):
        shapes += [(d,), (d, d), (d, d), (d, d), (d, d), (d,), (f, d), (d, f)]
    shapes += [(d,), (v, d)]
    return shapes
