"""Build-time training of the tiny model zoo on the synthetic corpus.

Runs ONCE under `make artifacts` (never on the request path). Each model
trains for a few hundred Adam steps — enough to pull perplexity far below
the unigram floor so quantization-induced degradation is measurable, per
the session contract's end-to-end requirement. Weights land in
`artifacts/<name>.llvqw` (the cross-language format of model/io.rs).
"""

from __future__ import annotations

import json
import struct
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.corpus import Corpus


def make_batches(seed: int, num_tokens: int, seq: int):
    c = Corpus(seed)
    toks, _ = c.generate(num_tokens)
    arr = np.asarray(toks, np.int32)
    n = (len(arr) - 1) // seq
    x = arr[: n * seq].reshape(n, seq)
    y = arr[1 : n * seq + 1].reshape(n, seq)
    return x, y


def adam_train(cfg: dict, steps: int, batch: int, lr: float, seed: int = 1000):
    key = jax.random.PRNGKey(cfg["d_model"] * 7 + cfg["n_layers"])
    params = M.init_params(cfg, key)
    seq = cfg["max_seq"]
    x_all, y_all = make_batches(seed, steps * batch * seq + seq + 1, seq)

    loss_grad = jax.jit(
        jax.value_and_grad(lambda p, x, y: M.loss_fn(p, x, y, cfg))
    )

    flat, tree = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(t) for t in flat]
    v = [jnp.zeros_like(t) for t in flat]
    b1, b2, eps = 0.9, 0.95, 1e-8

    t0 = time.time()
    last = None
    for step in range(steps):
        lo = (step * batch) % (x_all.shape[0] - batch)
        xb = jnp.asarray(x_all[lo : lo + batch])
        yb = jnp.asarray(y_all[lo : lo + batch])
        loss, grads = loss_grad(params, xb, yb)
        gflat, _ = jax.tree_util.tree_flatten(grads)
        t = step + 1
        lr_t = lr * min(1.0, t / 50.0)  # linear warmup
        new_flat = []
        for i, (p, g) in enumerate(zip(jax.tree_util.tree_flatten(params)[0], gflat)):
            m[i] = b1 * m[i] + (1 - b1) * g
            v[i] = b2 * v[i] + (1 - b2) * g * g
            mh = m[i] / (1 - b1 ** t)
            vh = v[i] / (1 - b2 ** t)
            new_flat.append(p - lr_t * mh / (jnp.sqrt(vh) + eps))
        params = jax.tree_util.tree_unflatten(tree, new_flat)
        last = float(loss)
        if step % 50 == 0 or step == steps - 1:
            print(f"  [{cfg['name']}] step {step:4d} loss {last:.4f} "
                  f"ppl {np.exp(last):7.2f} ({time.time()-t0:.0f}s)", flush=True)
    return params, last


def save_llvqw(params: dict, cfg: dict, path: Path):
    """Write the cross-language .llvqw format (see rust/src/model/io.rs)."""
    header = json.dumps(
        {k: cfg[k] for k in ("name", "vocab", "d_model", "n_layers", "n_heads", "d_ff", "max_seq")},
        separators=(",", ":"), sort_keys=True,
    ).encode()
    with open(path, "wb") as f:
        f.write(b"LLVQWTS1")
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for t in M.params_to_flat(params):
            f.write(np.asarray(t, np.float32).tobytes())


def train_zoo(out_dir: Path, steps: int = 260, batch: int = 16, lr: float = 3e-3):
    out_dir.mkdir(parents=True, exist_ok=True)
    results = {}
    for cfg in M.config_zoo():
        path = out_dir / f"{cfg['name']}.llvqw"
        if path.exists():
            print(f"  [{cfg['name']}] exists, skipping")
            continue
        print(f"training {cfg['name']} …", flush=True)
        params, loss = adam_train(cfg, steps, batch, lr)
        save_llvqw(params, cfg, path)
        results[cfg["name"]] = loss
        print(f"  [{cfg['name']}] final loss {loss:.4f} → {path}")
    return results


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 260
    train_zoo(Path(__file__).resolve().parent.parent.parent / "artifacts", steps=steps)
