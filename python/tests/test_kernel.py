"""L1 kernel correctness: Pallas/jnp dequantizer vs the pure-python oracle.

This is the CORE correctness signal of the compile path (kernel vs ref
allclose), plus hypothesis-style sweeps over batch shapes, tile sizes and
index distributions.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from compile import leech  # noqa: E402
from compile.kernels import llvq_dequant as kd  # noqa: E402
from compile.kernels.ref import dequantize_ref  # noqa: E402

TABLES = leech.build_tables(4)
TB = kd.tables_to_arrays(TABLES)


def _random_indices(rng, n):
    return rng.integers(0, TABLES.num_points(), n, dtype=np.int64)


def test_jnp_dequant_matches_ref_oracle():
    rng = np.random.default_rng(7)
    idx = _random_indices(rng, 256)
    # pin structural boundaries
    idx[:6] = [0, 1, 196_559, 196_560, TABLES.num_points() - 1, 16_969_680]
    out = np.asarray(kd.dequant_batch(jnp.asarray(idx), TB))
    for i, ix in enumerate(idx):
        assert list(out[i]) == dequantize_ref(TABLES, int(ix)), f"idx {ix}"


def test_outputs_are_lattice_points_with_correct_norms():
    rng = np.random.default_rng(8)
    idx = _random_indices(rng, 200)
    out = np.asarray(kd.dequant_batch(jnp.asarray(idx), TB))
    n = leech.theta_shell_sizes(4)
    cum = {2: n[2], 3: n[2] + n[3], 4: n[2] + n[3] + n[4]}
    for i, ix in enumerate(idx):
        x = [int(v) for v in out[i]]
        assert leech.is_lattice_point(x), f"idx {ix} → non-lattice {x}"
        norm = sum(v * v for v in x)
        # shell implied by the index range must match the point's norm
        m_expected = next(m for m in (2, 3, 4) if ix < cum[m])
        assert norm == 16 * m_expected, f"idx {ix}: norm {norm} ≠ shell {m_expected}"


@pytest.mark.parametrize("tile", [1, 2, 64, 256])
def test_pallas_matches_jnp_across_tiles(tile):
    rng = np.random.default_rng(tile)
    idx = _random_indices(rng, 256)
    a = np.asarray(kd.dequant_batch(jnp.asarray(idx), TB))
    b = np.asarray(kd.pallas_dequant(jnp.asarray(idx), TB, tile=tile))
    assert (a == b).all()


@pytest.mark.parametrize("n", [8, 24, 768])
def test_batch_shapes(n):
    rng = np.random.default_rng(n)
    idx = _random_indices(rng, n)
    out = np.asarray(kd.dequant_batch(jnp.asarray(idx), TB))
    assert out.shape == (n, 24)
    assert out.dtype == np.int32


def test_full_shell2_sweep_matches_ref():
    """Exhaustive agreement on a uniform stride through Shell(2)."""
    idx = np.arange(0, 196_560, 997, dtype=np.int64)
    out = np.asarray(kd.dequant_batch(jnp.asarray(idx), TB))
    for i, ix in enumerate(idx):
        assert list(out[i]) == dequantize_ref(TABLES, int(ix))
        assert sum(int(v) ** 2 for v in out[i]) == 32  # shell 2 norm


def test_dequant_f32_scaling():
    idx = jnp.asarray([0, 5, 100], dtype=jnp.int64)
    pts = np.asarray(kd.dequant_batch(idx, TB), dtype=np.float64)
    got = np.asarray(kd.dequant_f32(idx, TB, 2.0))
    np.testing.assert_allclose(got, pts / np.sqrt(8.0) * 2.0, rtol=1e-6)


def test_quantized_linear_against_manual():
    from compile import model as M

    rows = cols = 24 * 2
    nblocks = rows * cols // 24
    rng = np.random.default_rng(3)
    idx = _random_indices(rng, nblocks)
    gains = rng.random(nblocks, dtype=np.float32) * 0.2
    x = rng.standard_normal((4, cols)).astype(np.float32)
    y = np.asarray(
        M.quantized_linear(
            jnp.asarray(idx), jnp.asarray(gains), TB, jnp.asarray(x), rows, cols,
            use_pallas=False,
        )
    )
    pts = np.asarray(kd.dequant_batch(jnp.asarray(idx), TB), dtype=np.float32)
    w_hat = (pts * gains[:, None]).reshape(rows, cols)
    np.testing.assert_allclose(y, x @ w_hat.T, rtol=1e-4, atol=1e-4)
