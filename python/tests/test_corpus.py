"""Cross-language corpus agreement (golden prefix generated from the rust
implementation — both suites pin the same constant)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from compile.corpus import VOCAB, Corpus, Xoshiro256pp

# first 64 tokens of Corpus::new(1234).generate(64) in rust/src/model/corpus.rs
GOLDEN_1234 = [
    58, 7, 5, 18, 19, 22, 32, 43, 37, 28, 52, 21, 13, 50, 30, 30, 41, 4, 16, 14, 18, 42, 56, 4, 28, 58, 58, 7, 63, 2, 7, 11, 35, 53, 31, 20, 32, 11, 27, 16, 28, 46, 61, 32, 43, 37, 19, 1, 59, 5, 37, 53, 31, 35, 7, 11, 43, 37, 23, 39, 61, 52, 29, 58,
]


def test_golden_prefix_matches_rust():
    toks, _ = Corpus(1234).generate(64)
    assert toks == GOLDEN_1234


def test_rng_is_deterministic_and_bounded():
    a = Xoshiro256pp(42)
    b = Xoshiro256pp(42)
    for _ in range(1000):
        assert a.next_u64() == b.next_u64()
    r = Xoshiro256pp(7)
    for _ in range(1000):
        assert 0 <= r.next_range(97) < 97


def test_tokens_in_vocab_and_motifs_present():
    c = Corpus(99)
    toks, det = c.generate(20_000)
    assert all(0 <= t < VOCAB for t in toks)
    frac = sum(det) / len(det)
    assert 0.02 < frac < 0.35


def test_different_seeds_differ():
    a, _ = Corpus(1).generate(500)
    b, _ = Corpus(2).generate(500)
    assert a != b
