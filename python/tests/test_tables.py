"""Shell/class enumeration + kernel-table validation, incl. the
cross-language equality check against the rust JSON export when present."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import pytest

from compile.leech import build_tables, enumerate_shell, theta_shell_sizes

ROOT = Path(__file__).resolve().parent.parent.parent


@pytest.mark.parametrize("m", range(2, 9))
def test_enumeration_matches_theta(m):
    total = sum(c.size for c in enumerate_shell(m))
    assert total == theta_shell_sizes(m)[m], f"shell {m} mismatch"


def test_shell2_composition_table2():
    classes = enumerate_shell(2)
    sizes = sorted(c.size for c in classes)
    assert sizes == [1104, 97152, 98304]
    parities = {c.parity for c in classes}
    assert parities == {"even", "odd"}


def test_shell4_composition_table2():
    sizes = sorted(c.size for c in enumerate_shell(4))
    assert sizes == [48, 170016, 777216, 24870912, 24870912, 46632960, 126615552, 174096384]


def test_tables_consistency_small():
    t = build_tables(3)
    assert t.num_points() == 16_969_680
    assert t.index_bits() == 25
    assert len(t.group_offsets) == t.num_groups + 1
    assert all(a < b for a, b in zip(t.group_offsets, t.group_offsets[1:]))
    # per-group size identity: A · 2^B · arr_f1 · arr_f0
    for g in range(t.num_groups):
        size = t.group_offsets[g + 1] - t.group_offsets[g]
        expect = (
            t.num_codewords[g]
            * (1 << t.sign_bits[g])
            * t.f1_arrangements[g]
            * t.f0_arrangements[g]
        )
        assert size == expect


def test_cross_language_tables_match_rust():
    """Compare against `llvq tables --out artifacts/tables.rust.json` when
    the export exists (written by `make test`)."""
    path = ROOT / "artifacts" / "tables.rust.json"
    if not path.exists():
        pytest.skip("rust table export not present")
    rust = json.loads(path.read_text())
    t = build_tables(int(rust["max_m"]))
    assert rust["num_groups"] == t.num_groups
    assert rust["group_offsets"] == t.group_offsets
    for key in (
        "weight", "num_codewords", "cw_base", "sign_bits", "parity_odd",
        "f1_neg_parity", "f0_arrangements", "f1_arrangements",
        "f1_values", "f1_counts", "f0_values", "f0_counts",
        "golay_sorted", "weight_offsets",
    ):
        assert rust[key] == getattr(t, key), f"table '{key}' differs from rust"
