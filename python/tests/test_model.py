"""L2 model tests: shapes, causality, trainability, serialization."""

import struct
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from compile import model as M  # noqa: E402

CFG = M.config_by_name("qwen3-4b-tiny")


def _params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_forward_shapes_finite():
    p = _params()
    toks = jnp.asarray(np.arange(32).reshape(2, 16) % 64, jnp.int32)
    logits = M.forward(p, toks, CFG)
    assert logits.shape == (2, 16, CFG["vocab"])
    assert bool(jnp.isfinite(logits).all())


def test_causality():
    p = _params()
    a = np.arange(20) % 64
    b = a.copy()
    b[15:] = 9
    la = M.forward(p, jnp.asarray(a[None], jnp.int32), CFG)
    lb = M.forward(p, jnp.asarray(b[None], jnp.int32), CFG)
    np.testing.assert_allclose(la[0, :15], lb[0, :15], atol=1e-5)


def test_loss_decreases_with_training():
    from compile.train import adam_train

    cfg = dict(CFG, n_layers=1, d_model=48, n_heads=2, d_ff=96)
    _, final_loss = adam_train(cfg, steps=60, batch=8, lr=3e-3)
    # unigram floor on this corpus is well under log(64)=4.16; training even
    # briefly must cut the uniform loss substantially
    assert final_loss < 3.7, f"final loss {final_loss}"


def test_flat_roundtrip_and_llvqw_header():
    from compile.train import save_llvqw

    p = _params()
    flat = M.params_to_flat(p)
    assert [tuple(t.shape) for t in flat] == [tuple(s) for s in M.flat_shapes(CFG)]
    back = M.flat_to_params(flat, CFG)
    np.testing.assert_array_equal(back["lm_head"], p["lm_head"])

    out = Path("/tmp/_llvq_test.llvqw")
    save_llvqw(p, CFG, out)
    data = out.read_bytes()
    assert data[:8] == b"LLVQWTS1"
    (hlen,) = struct.unpack("<I", data[8:12])
    header = data[12 : 12 + hlen].decode()
    assert '"d_model":120' in header
    # byte count: header + 4 bytes per param
    n_params = sum(int(np.prod(s)) for s in M.flat_shapes(CFG))
    assert len(data) == 12 + hlen + 4 * n_params
    out.unlink()
