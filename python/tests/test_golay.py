"""Golay code + theta series validation (python twin of golay.rs tests)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import pytest

from compile.leech import (
    WEIGHTS,
    golay_by_weight,
    golay_codewords,
    theta_shell_sizes,
)


def test_weight_distribution():
    wd = {}
    for c in golay_codewords():
        wd[bin(c).count("1")] = wd.get(bin(c).count("1"), 0) + 1
    assert wd == {0: 1, 8: 759, 12: 2576, 16: 759, 24: 1}


def test_linearity_closure():
    cws = golay_codewords()
    s = set(cws)
    for i in range(0, 4096, 97):
        for j in range(0, 4096, 113):
            assert cws[i] ^ cws[j] in s


def test_min_distance_8():
    assert min(bin(c).count("1") for c in golay_codewords()[1:]) == 8


def test_doubly_even():
    for c in golay_codewords():
        assert bin(c).count("1") % 4 == 0


@pytest.mark.parametrize("w", WEIGHTS)
def test_weight_buckets_sorted_and_sized(w):
    bucket = golay_by_weight()[w]
    assert bucket == sorted(bucket)
    expect = {0: 1, 8: 759, 12: 2576, 16: 759, 24: 1}[w]
    assert len(bucket) == expect


def test_theta_series_table1():
    n = theta_shell_sizes(13)
    assert n[2] == 196_560
    assert n[3] == 16_773_120
    assert n[4] == 398_034_000
    assert n[5] == 4_629_381_120
    # paper Table 1 prints n(13) with a dropped digit; cumulative pins it
    assert n[13] == 169_931_095_326_720
    assert sum(n[2:14]) == 280_974_212_784_720
