#!/usr/bin/env bash
# Repo verification gate: build, tests, and a warnings-as-errors clippy
# pass. CI and pre-merge checks run exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
cargo clippy -q -- -D warnings
