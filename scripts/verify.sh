#!/usr/bin/env bash
# Repo verification gate: build, tests, a warnings-as-errors clippy
# pass over EVERY target (lib, bins, examples, integration tests, and the
# bench harnesses — which tier-1 `cargo test` never compiles), a
# warnings-as-errors rustdoc build (broken intra-doc links are doc
# drift), and the repo-native lint engine (`llvq lint`, rules in
# LINTS.md — including docs-sync over docs/) which exits non-zero on
# any finding. CI and pre-merge checks run exactly this.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q
cargo clippy -q --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo run --release --quiet -- lint
