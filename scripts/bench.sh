#!/usr/bin/env bash
# Run every bench harness that emits BENCH_*.json rows and leave the
# files in the repo root (the kernel baseline BENCH_kernel.json is the
# only one under version control — refresh it with this script). The
# serving harness now also writes BENCH_kv.json: the paged-KV capacity
# comparison (sessions-per-GB for dense vs paged vs paged+llvq cold
# pages) plus measured decode tok/s across the three cache modes.
#
# Defaults to smoke mode (LLVQ_BENCH_SMOKE=1: shrunken iteration counts
# and codebook dims, rows tagged "smoke": true) so a laptop or CI runner
# produces every file in seconds; export LLVQ_BENCH_SMOKE=0 for the full
# measurement sweep. LLVQ_SIMD=off|scalar|avx2|neon|portable forces the
# fused kernel the serving benches dispatch (default: auto-detection —
# the simd-vs-scalar section always measures the forced-scalar baseline
# alongside whatever detection picks).
set -euo pipefail
cd "$(dirname "$0")/.."

export LLVQ_BENCH_SMOKE="${LLVQ_BENCH_SMOKE:-1}"

cargo bench --bench packed
cargo bench --bench serving
ls -l BENCH_*.json
