#!/usr/bin/env bash
# Run every bench harness that emits BENCH_*.json rows and leave the
# files in the repo root. BENCH_*.json is under version control (not
# gitignored): commit the refreshed files alongside the change they
# measure, so the perf trajectory lives in the tree rather than only in
# CI workflow artifacts. The serving harness writes BENCH_serving.json
# (backend rows plus a "sim" suite: one row per deterministic
# scheduler-simulator scenario — wall time, virtual ticks, counters,
# invariant verdict, determinism fingerprint), BENCH_generation.json,
# BENCH_kernel.json, BENCH_prefill.json, and BENCH_kv.json; the packed
# harness writes BENCH_packed.json.
#
# Defaults to smoke mode (LLVQ_BENCH_SMOKE=1: shrunken iteration counts
# and codebook dims, rows tagged "smoke": true) so a laptop or CI runner
# produces every file in seconds; export LLVQ_BENCH_SMOKE=0 for the full
# measurement sweep. LLVQ_SIMD=off|scalar|avx2|neon|portable forces the
# fused kernel the serving benches dispatch (default: auto-detection —
# the simd-vs-scalar section always measures the forced-scalar baseline
# alongside whatever detection picks).
set -euo pipefail
cd "$(dirname "$0")/.."

export LLVQ_BENCH_SMOKE="${LLVQ_BENCH_SMOKE:-1}"

cargo bench --bench packed
cargo bench --bench serving
ls -l BENCH_*.json
